"""Vertical / split federated learning — the latency-bound workload.

Horizontal FL ships one model-sized update per client per round; the
paper's §VII decision table (gRPC below the ~10 MB knee, gRPC+S3 above)
is derived from that wire profile. Vertical / split FL inverts it: the
model is cut at a layer boundary into a *bottom* (feature party, holds
the inputs) and a *top* (label party, holds the labels), and every
training batch crosses the wire twice — forward activations up,
activation gradients back. Per-message payloads are small (a batch of
hidden states, not a parameter tree) but there are ``2 * batches_per_
round`` of them per client per round, so per-message latency dominates
and store round-trips (two S3 REST latencies per hop) are poison. This
module provides:

* ``SplitPlan``        — cuts any model-zoo model (ResNet / MobileNetV3 /
  dense TransformerLM) at a configurable unit boundary; split forward +
  backward compose to exactly the unsplit model's numerics (tested).
* ``VerticalStrategy`` — an ``AggregationStrategy`` driving the per-batch
  activation/gradient exchange as first-class EventLoop events, with
  batch-level pipelining: the feature party computes batch *i+1* while
  batch *i*'s activations are still in flight. All traffic flows through
  the backends' ``Channel.encode/decode`` stacks, so qsgd/topk error
  feedback (per direction), zlib wire codecs, chunking + LinkFaultModel
  retransmit, churn, and AUTO per-message routing apply unmodified.

Who ships what: the feature party (client) ships activations and is
charged the client->server wire time; the label party (server) ships
activation gradients and is charged the server->client wire time; the
round-close bookkeeping reuses ``FLScheduler.aggregate`` with small
virtual records (a vertical round updates parties in place — there is
no model-sized merge).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.message import FLMessage, TensorPayload, VirtualPayload
from repro.fl.async_strategies import AggregationStrategy
from repro.fl.scheduler import FLScheduler, UpdateRecord


# ---------------------------------------------------------------------------
# SplitPlan: cut a zoo model into bottom (feature party) / top (label party)
# ---------------------------------------------------------------------------

class _ResNetAdapter:
    """Cut between residual blocks (stem is always bottom, head always
    top); unit i is the i-th block in (stage, block) order."""

    def __init__(self, model):
        self.model = model
        cfg = model.cfg
        self.coords = [(si, bi) for si in range(len(cfg.widths))
                       for bi in range(cfg.blocks_per_stage)]

    @property
    def n_units(self) -> int:
        return len(self.coords)

    def split_params(self, p, cut: int):
        blocks = [p[f"stage{si}"][bi] for (si, bi) in self.coords]
        bottom = {"stem": p["stem"], "blocks": list(blocks[:cut])}
        top = {"blocks": list(blocks[cut:]), "head": p["head"]}
        return bottom, top

    def merge_params(self, bottom, top):
        cfg = self.model.cfg
        blocks = list(bottom["blocks"]) + list(top["blocks"])
        p = {"stem": bottom["stem"]}
        bps = cfg.blocks_per_stage
        for si in range(len(cfg.widths)):
            p[f"stage{si}"] = blocks[si * bps:(si + 1) * bps]
        p["head"] = top["head"]
        return p

    def _block(self, blk, x, si, bi):
        from repro.models.vision import conv, norm_apply
        stride = 2 if (si > 0 and bi == 0) else 1
        h = jax.nn.relu(norm_apply(blk["bn1"], conv(x, blk["c1"], stride)))
        h = norm_apply(blk["bn2"], conv(h, blk["c2"]))
        sc = conv(x, blk["proj"], stride) if "proj" in blk else x
        return jax.nn.relu(h + sc)

    def bottom_forward(self, bottom, batch, cut: int):
        from repro.models.vision import conv, norm_apply
        x = norm_apply(bottom["stem"]["bn"],
                       conv(batch["images"], bottom["stem"]["w"]))
        x = jax.nn.relu(x)
        for i, blk in enumerate(bottom["blocks"]):
            x = self._block(blk, x, *self.coords[i])
        return x

    def top_loss(self, top, acts, batch, cut: int):
        from repro.models import layers as L
        x = acts
        for j, blk in enumerate(top["blocks"]):
            x = self._block(blk, x, *self.coords[cut + j])
        x = jnp.mean(x, axis=(1, 2))
        logits = x @ top["head"]["w"] + top["head"]["b"]
        return L.cross_entropy(logits[:, None, :], batch["labels"][:, None],
                               z_loss=0.0), {}


class _MobileNetAdapter:
    """Cut between inverted-residual blocks (stem bottom, head top)."""

    def __init__(self, model):
        self.model = model

    @property
    def n_units(self) -> int:
        return len(self.model.cfg.blocks)

    def split_params(self, p, cut: int):
        bottom = {"stem": p["stem"], "blocks": list(p["blocks"][:cut])}
        top = {"blocks": list(p["blocks"][cut:]), "head": p["head"]}
        return bottom, top

    def merge_params(self, bottom, top):
        return {"stem": bottom["stem"],
                "blocks": list(bottom["blocks"]) + list(top["blocks"]),
                "head": top["head"]}

    def _block(self, blk, x, spec):
        from repro.models.vision import conv, norm_apply
        (_, _, stride, _) = spec
        h = jax.nn.hard_swish(norm_apply(blk["bn_e"], conv(x, blk["expand"])))
        c_mid = h.shape[-1]
        h = jax.nn.hard_swish(norm_apply(
            blk["bn_d"], conv(h, blk["dw"], stride, groups=c_mid)))
        if "se_down" in blk:
            s = jnp.mean(h, axis=(1, 2), keepdims=True)
            s = jax.nn.relu(conv(s, blk["se_down"]))
            s = jax.nn.sigmoid(conv(s, blk["se_up"]))
            h = h * s
        h = norm_apply(blk["bn_p"], conv(h, blk["project"]))
        if stride == 1 and h.shape[-1] == x.shape[-1]:
            h = h + x
        return h

    def bottom_forward(self, bottom, batch, cut: int):
        from repro.models.vision import conv, norm_apply
        cfg = self.model.cfg
        x = jax.nn.hard_swish(norm_apply(
            bottom["stem"]["bn"], conv(batch["images"], bottom["stem"]["w"],
                                       2)))
        for spec, blk in zip(cfg.blocks[:cut], bottom["blocks"]):
            x = self._block(blk, x, spec)
        return x

    def top_loss(self, top, acts, batch, cut: int):
        from repro.models import layers as L
        from repro.models.vision import conv, norm_apply
        cfg = self.model.cfg
        x = acts
        for spec, blk in zip(cfg.blocks[cut:], top["blocks"]):
            x = self._block(blk, x, spec)
        head = top["head"]
        x = jax.nn.hard_swish(norm_apply(head["bn"], conv(x, head["w"])))
        x = jnp.mean(x, axis=(1, 2))
        x = jax.nn.hard_swish(x @ head["fc1"])
        logits = x @ head["fc2"] + head["b"]
        return L.cross_entropy(logits[:, None, :], batch["labels"][:, None],
                               z_loss=0.0), {}


class _TransformerAdapter:
    """Cut between transformer layers of a plain dense stack: the token
    embedding table rides with the bottom (the feature party holds the
    raw tokens), the LM head + final norm with the top."""

    def __init__(self, model):
        cfg = model.cfg
        if model.segments != [(("self",), cfg.num_layers)]:
            raise ValueError(
                f"SplitPlan: only plain dense stacks are splittable; "
                f"{cfg.name} plans segments {model.segments}")
        if cfg.tie_embeddings:
            raise ValueError(
                "SplitPlan: tie_embeddings couples the bottom's embedding "
                "table to the top's LM head — untie to split")
        if cfg.external_embeddings:
            raise ValueError("SplitPlan: external-embedding (encoder-only) "
                             "models have no token side to cut at")
        self.model = model

    @property
    def n_units(self) -> int:
        return self.model.cfg.num_layers

    def split_params(self, p, cut: int):
        seg = p["seg0"]["b0_self"]  # layers stacked on the leading axis
        bottom = {"embedding": p["embed"]["embedding"],
                  "layers": jax.tree.map(lambda a: a[:cut], seg)}
        top = {"lm_head": p["embed"]["lm_head"],
               "final_norm": p["embed"]["final_norm"],
               "layers": jax.tree.map(lambda a: a[cut:], seg)}
        return bottom, top

    def merge_params(self, bottom, top):
        seg = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                           bottom["layers"], top["layers"])
        embed = {"embedding": bottom["embedding"],
                 "lm_head": top["lm_head"],
                 "final_norm": top["final_norm"]}
        return {"embed": embed, "seg0": {"b0_self": seg}}

    def _run_layers(self, layers, x, positions):
        model = self.model
        n = jax.tree.leaves(layers)[0].shape[0]
        for i in range(n):
            lp = jax.tree.map(lambda a, _i=i: a[_i], layers)
            x, _ = model._block_apply("self", lp, x, positions=positions)
        return x

    def bottom_forward(self, bottom, batch, cut: int):
        from repro.models import layers as L
        model, cfg = self.model, self.model.cfg
        x = L.embed_lookup({"embedding": bottom["embedding"]},
                           batch["tokens"], cfg, jnp.dtype(cfg.dtype))
        x = model.sharder(x, ("batch", "seq", None))
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        return self._run_layers(bottom["layers"], x, positions)

    def top_loss(self, top, acts, batch, cut: int):
        from repro.models import layers as L
        model, cfg = self.model, self.model.cfg
        positions = jnp.arange(acts.shape[1], dtype=jnp.int32)
        x = self._run_layers(top["layers"], acts, positions)
        logits = L.lm_logits({"lm_head": top["lm_head"],
                              "final_norm": top["final_norm"]}, x, cfg)
        logits = model.sharder(logits, ("batch", "seq", "vocab"))
        ce = L.cross_entropy(logits, batch["targets"])
        return ce, {}  # dense self blocks carry zero aux loss


def _adapter_for(model):
    from repro.models.transformer import TransformerLM
    from repro.models.vision import MobileNetV3, ResNet
    if isinstance(model, ResNet):
        return _ResNetAdapter(model)
    if isinstance(model, MobileNetV3):
        return _MobileNetAdapter(model)
    if isinstance(model, TransformerLM):
        return _TransformerAdapter(model)
    raise TypeError(f"SplitPlan: no split adapter for "
                    f"{type(model).__name__} (splittable: ResNet, "
                    f"MobileNetV3, dense TransformerLM)")


class SplitPlan:
    """A vertical cut of one zoo model at unit boundary ``cut_layer``.

    The feature party owns units ``[0, cut_layer)`` plus the input-side
    extras (conv stem / token embedding); the label party owns units
    ``[cut_layer, n_units)`` plus the output head. ``bottom_forward`` +
    ``top_loss`` compose to exactly the unsplit model's ``loss`` and
    ``split_params`` / ``merge_params`` round-trip the parameter tree —
    both properties are what tests/test_vertical.py locks."""

    def __init__(self, model, cut_layer: int):
        self.model = model
        self.adapter = _adapter_for(model)
        self.cut_layer = int(cut_layer)
        n = self.adapter.n_units
        if not 1 <= self.cut_layer <= n - 1:
            raise ValueError(
                f"SplitPlan: cut_layer {cut_layer} out of range for "
                f"{type(model).__name__} — valid cuts are 1..{n - 1} "
                f"({n} splittable units)")

    @property
    def n_units(self) -> int:
        return self.adapter.n_units

    def split_params(self, params):
        """-> (bottom, top): disjoint parameter trees for the parties."""
        return self.adapter.split_params(params, self.cut_layer)

    def merge_params(self, bottom, top):
        """Inverse of ``split_params`` (exact tree round-trip)."""
        return self.adapter.merge_params(bottom, top)

    def bottom_forward(self, bottom, batch):
        """Feature-party forward: inputs -> cut-boundary activations."""
        return self.adapter.bottom_forward(bottom, batch, self.cut_layer)

    def top_loss(self, top, acts, batch):
        """Label-party loss from cut-boundary activations -> (loss, aux)."""
        return self.adapter.top_loss(top, acts, batch, self.cut_layer)

    def loss(self, bottom, top, batch):
        """Composed split loss — equals the unsplit ``model.loss``."""
        return self.top_loss(top, self.bottom_forward(bottom, batch), batch)


# ---------------------------------------------------------------------------
# sizing helpers (sim mode)
# ---------------------------------------------------------------------------

# Proxy unit depth per payload tier, for apportioning a tier's calibrated
# per-round train seconds between the bottom and top parties. Matches the
# zoo: resnet56 has 27 blocks, mobilenetv3 14, distilbert 6 layers, and
# vit-large 24.
TIER_DEPTH = {"small": 27, "medium": 14, "big": 6, "large": 24}

#: per-batch examples assumed when sizing simulated activation tensors
SIM_BATCH_SIZE = 32


def bottom_fraction(cut_layer: int, depth: int) -> float:
    """Fraction of one batch's compute the feature party performs."""
    return min(0.95, max(0.05, cut_layer / max(depth, 1)))


def sim_activation_nbytes(payload_bytes: float, batch_size: int,
                          cut_layer: int) -> int:
    """Activation-tensor bytes for one batch at the cut, from the tier's
    model payload size. A model of P parameter bytes has ~sqrt(P/4)
    hidden width; one batch of fp32 hidden states is ``batch * 4 *
    width`` bytes, halved per unit of cut depth (pooling/striding shrinks
    the feature map as the cut moves up). ~1 MB for the big tier at
    batch 32 and cut 1 — squarely below AUTO's 10 MB knee, which is the
    whole fig13 story."""
    width = math.sqrt(max(payload_bytes, 4.0) / 4.0)
    nbytes = batch_size * 4.0 * width / (2.0 ** (cut_layer - 1))
    return max(1024, int(nbytes))


# ---------------------------------------------------------------------------
# the live bundle (real tensors through the wire stack)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VerticalLive:
    """Real-compute mode: the strategy carries actual split parameters
    and runs SGD on both parties; activation/gradient payloads are real
    ``TensorPayload`` trees (so lossy codecs + error feedback act on
    them). ``batch_fn(client_id, round, batch) -> batch dict`` must be
    deterministic — both parties call it for their halves."""
    plan: SplitPlan
    bottoms: Dict[str, Any]  # client_id -> feature-party params
    top: Any  # label-party params (server side)
    batch_fn: Callable[[str, int, int], dict]
    lr: float = 0.05


# ---------------------------------------------------------------------------
# VerticalStrategy
# ---------------------------------------------------------------------------

class VerticalStrategy(AggregationStrategy):
    """Per-batch split-training rounds on the event loop.

    One round = ``batches_per_round`` forward/backward exchanges per
    feature party, all parties concurrent. Per client the pipeline is:

      server --round_start(meta)--> client
      client: compute bottom batch b   (computes run back-to-back:
              batch i+1 overlaps batch i's wire time)
      client --activation b--> server   (client's channel: codec + EF)
      server: top forward/backward on its per-party executor line
      server --grad b--> client         (server's channel: codec + EF)
      client: bottom backward           -> batch b complete

    The round closes when every registered batch completed or was
    abandoned (transfer failure after bounded retries, or churn); the
    close books one small virtual ``UpdateRecord`` per participating
    party through ``FLScheduler.aggregate`` — weight = completed
    batches — which bumps the version and starts the next round.
    """

    name = "vertical"

    #: give up on one message after this many send attempts (mirrors the
    #: scheduler's bounded upload retries)
    MAX_ATTEMPTS = 3
    #: consecutive rounds with zero completed batches before the
    #: strategy goes idle (a fully dead fabric must not spin the loop)
    MAX_EMPTY_ROUNDS = 25

    def __init__(self, *, cut_layer: int = 1, batches_per_round: int = 8,
                 activation_nbytes: int = 1 << 20, train_s: float = 20.0,
                 bottom_frac: float = 0.5, live: Optional[VerticalLive] = None):
        if cut_layer < 1:
            raise ValueError("cut_layer must be >= 1")
        if batches_per_round < 1:
            raise ValueError("batches_per_round must be >= 1")
        self.cut_layer = int(cut_layer)
        self.batches_per_round = int(batches_per_round)
        self.activation_nbytes = int(activation_nbytes)
        self.train_s = float(train_s)
        self.bottom_frac = float(bottom_frac)
        self.live = live
        b = self.batches_per_round
        self.bottom_s = self.train_s * self.bottom_frac / b
        self.top_s = self.train_s * (1.0 - self.bottom_frac) / b
        self.round_id = 0
        self.pending: Dict[str, int] = {}  # cid -> batches not yet resolved
        self.completed: Dict[str, int] = {}  # cid -> batches completed
        self._top_busy: Dict[str, float] = {}  # per-party top executor line
        self._vjp: Dict[tuple, Any] = {}  # (cid, round, batch) -> bottom vjp
        self._idle = False
        self._empty_rounds = 0
        self._closing = False

    # -- bootstrap ---------------------------------------------------------
    def start(self, sched: FLScheduler, now: float):
        self.sched = sched
        self._begin_round(sched, now)

    # -- round lifecycle ---------------------------------------------------
    def _begin_round(self, sched: FLScheduler, now: float):
        if sched.finished:
            return
        live = [c for c in sched.clients if sched.is_up(c.client_id)]
        if not live:
            # nothing to drive; churn joins re-enter via on_join
            self._idle = True
            return
        self._idle = False
        self._closing = False
        self.round_id = sched.version
        self.pending = {c.client_id: self.batches_per_round for c in live}
        self.completed = {c.client_id: 0 for c in live}
        for c in live:
            self._send_round_start(sched, c, now, 0)

    def _ctrl_msg(self, sched: FLScheduler, client) -> FLMessage:
        return FLMessage("round_start", sched.backend.host_id,
                         client.client_id, round=self.round_id,
                         metadata={"version": self.round_id})

    def _send_round_start(self, sched: FLScheduler, client, now: float,
                          attempt: int):
        cid = client.client_id
        if sched.finished or not sched.is_up(cid):
            self._abandon_party(sched, cid, now)
            return
        h = sched.backend.isend(self._ctrl_msg(sched, client), now)
        if sched._track(h, f"vstart>{cid}", self._on_client_msgs,
                        client=client):
            return
        if attempt + 1 < self.MAX_ATTEMPTS:
            sched.loop.call_at(
                max(now, h.start) + sched.redispatch_backoff_s,
                f"vstart-retry>{cid}",
                lambda t, c=client, a=attempt: self._send_round_start(
                    sched, c, t, a + 1))
        else:
            self._abandon_party(sched, cid, now)

    def _abandon_party(self, sched: FLScheduler, cid: str, now: float):
        """This party sits the round out (unreachable or departed)."""
        if self.pending.pop(cid, None) is not None:
            self._maybe_close(sched, now)

    # -- client side -------------------------------------------------------
    def _on_client_msgs(self, now: float, client):
        """Drain one feature party's inbox: round_start bootstraps the
        batch pipeline, grads complete batches."""
        sched = self.sched
        for msg, ready in client.backend.recv(now):
            if msg.msg_type == "round_start":
                if msg.round != self.round_id or sched.finished:
                    continue  # stale bootstrap from a closed round
                if not sched.is_up(client.client_id):
                    continue
                # pipelined computes: batch b finishes its bottom pass at
                # ready + (b+1)*bottom_s and its isend is non-blocking, so
                # batch i+1 computes while batch i's activations fly
                sched.loop.call_at_many(
                    [(ready + (b + 1) * self.bottom_s,
                      f"vact>{client.client_id}", self._send_activation,
                      dict(client=client, round_=msg.round, batch=b,
                           attempt=0))
                     for b in range(self.batches_per_round)])
            elif msg.msg_type == "grad":
                sched.loop.call_at(ready, f"vbwd<{client.client_id}",
                                   self._on_grad, client=client, msg=msg)

    def _send_activation(self, now: float, client, round_: int, batch: int,
                         attempt: int):
        sched = self.sched
        cid = client.client_id
        if sched.finished or round_ != self.round_id:
            return
        if not sched.is_up(cid) or cid not in self.pending:
            return
        if self.live is not None:
            data = self.live.batch_fn(cid, round_, batch)
            acts, vjp = jax.vjp(
                lambda p: self.live.plan.bottom_forward(p, data),
                self.live.bottoms[cid])
            self._vjp[(cid, round_, batch)] = vjp
            payload = TensorPayload({"acts": acts})
        else:
            payload = VirtualPayload(self.activation_nbytes,
                                     tag=f"act:{cid}:r{round_}:b{batch}")
        msg = FLMessage("activation", cid, sched.backend.host_id,
                        round=round_, payload=payload,
                        metadata={"batch": batch})
        h = client.backend.isend(msg, now)
        if sched._track(h, f"vact-arrive<{cid}", self._on_server_msgs):
            return
        if attempt + 1 < self.MAX_ATTEMPTS:
            sched.loop.call_at(
                max(now, h.start) + sched.redispatch_backoff_s,
                f"vact-retry>{cid}", self._send_activation, client=client,
                round_=round_, batch=batch, attempt=attempt + 1)
        else:
            sched.discarded += 1
            self._vjp.pop((cid, round_, batch), None)
            self._batch_done(sched, cid, round_, now, ok=False)

    # -- server side -------------------------------------------------------
    def _on_server_msgs(self, now: float):
        sched = self.sched
        for msg, ready in sched.backend.recv(now):
            if msg.msg_type != "activation":
                continue
            sched.loop.call_at(ready, f"vtop<{msg.sender}",
                               self._on_activation, msg=msg)

    def _on_activation(self, now: float, msg: FLMessage):
        sched = self.sched
        cid = msg.sender
        if sched.finished or msg.round != self.round_id:
            sched.discarded += 1  # landed after its round closed
            return
        if cid not in self.pending:
            return  # party abandoned / churned out mid-round
        client = sched._by_id.get(cid)
        batch = int(msg.metadata.get("batch", 0))
        # per-party top executor: one serialized compute line per feature
        # party (parties are independent label-side jobs), so activations
        # queue behind the previous batch of the *same* party only
        start = max(now, self._top_busy.get(cid, 0.0))
        done = start + self.top_s
        self._top_busy[cid] = done
        if self.live is not None:
            data = self.live.batch_fn(cid, msg.round, batch)
            acts = msg.payload.tree["acts"]

            def top_obj(top, a):
                return self.live.plan.top_loss(top, a, data)[0]

            loss, (g_top, g_acts) = jax.value_and_grad(
                top_obj, argnums=(0, 1))(self.live.top, acts)
            lr = self.live.lr
            self.live.top = jax.tree.map(lambda p, g: p - lr * g,
                                         self.live.top, g_top)
            if client is not None:
                client.last_loss = float(loss)
            payload = TensorPayload({"g": g_acts})
        else:
            payload = VirtualPayload(
                self.activation_nbytes,
                tag=f"grad:{cid}:r{msg.round}:b{batch}")
        sched.loop.call_at(done, f"vgrad>{cid}", self._send_grad,
                           client=client, round_=msg.round, batch=batch,
                           payload=payload, attempt=0)

    def _send_grad(self, now: float, client, round_: int, batch: int,
                   payload, attempt: int):
        sched = self.sched
        cid = client.client_id
        if sched.finished or round_ != self.round_id:
            return
        if not sched.is_up(cid) or cid not in self.pending:
            return
        msg = FLMessage("grad", sched.backend.host_id, cid, round=round_,
                        payload=payload, metadata={"batch": batch})
        h = sched.backend.isend(msg, now)
        if sched._track(h, f"vgrad-arrive>{cid}", self._on_client_msgs,
                        client=client):
            return
        if attempt + 1 < self.MAX_ATTEMPTS:
            sched.loop.call_at(
                max(now, h.start) + sched.redispatch_backoff_s,
                f"vgrad-retry>{cid}", self._send_grad, client=client,
                round_=round_, batch=batch, payload=payload,
                attempt=attempt + 1)
        else:
            sched.discarded += 1
            self._vjp.pop((cid, round_, batch), None)
            self._batch_done(sched, cid, round_, now, ok=False)

    def _on_grad(self, now: float, client, msg: FLMessage):
        sched = self.sched
        cid = client.client_id
        if sched.finished or msg.round != self.round_id:
            sched.discarded += 1
            return
        if cid not in self.pending:
            return
        batch = int(msg.metadata.get("batch", 0))
        if self.live is not None:
            vjp = self._vjp.pop((cid, msg.round, batch), None)
            if vjp is not None:
                (g_bottom,) = vjp(msg.payload.tree["g"])
                lr = self.live.lr
                self.live.bottoms[cid] = jax.tree.map(
                    lambda p, g: p - lr * g, self.live.bottoms[cid],
                    g_bottom)
        self._batch_done(sched, cid, msg.round, now, ok=True)

    # -- round close -------------------------------------------------------
    def _batch_done(self, sched: FLScheduler, cid: str, round_: int,
                    now: float, *, ok: bool):
        if round_ != self.round_id or cid not in self.pending:
            return
        self.pending[cid] -= 1
        if ok:
            self.completed[cid] = self.completed.get(cid, 0) + 1
        if self.pending[cid] <= 0:
            del self.pending[cid]
        self._maybe_close(sched, now)

    def _maybe_close(self, sched: FLScheduler, now: float):
        if self._closing or self.pending or sched.finished:
            return
        self._closing = True
        records = []
        for cid, n_done in self.completed.items():
            if n_done <= 0:
                continue
            records.append(UpdateRecord(
                client=sched._by_id.get(cid),
                payload=VirtualPayload(self.activation_nbytes,
                                       tag=f"vupd:{cid}:r{self.round_id}"),
                weight=float(n_done), version=self.round_id, staleness=0,
                arrive_t=now, count=1))
        if records:
            self._empty_rounds = 0
            done = sched.aggregate(records, now)
        else:
            self._empty_rounds += 1
            if self._empty_rounds >= self.MAX_EMPTY_ROUNDS:
                self._idle = True  # dead fabric: stop driving the loop
                return
            done = now + sched.redispatch_backoff_s
        if not sched.loop.stopped:
            self._begin_round(sched, done)

    # -- churn -------------------------------------------------------------
    def on_update(self, sched: FLScheduler, rec: UpdateRecord, now: float):
        pass  # vertical traffic never reaches the client_update path

    def on_leave(self, sched: FLScheduler, client, now: float):
        """A feature party departed mid-round: its in-flight batches die
        (round/membership guards drop late arrivals) and the round closes
        without it. Batches it already completed still count."""
        self._abandon_party(sched, client.client_id, now)

    def on_join(self, sched: FLScheduler, client, now: float):
        """(Re)joined parties fold in at the next round boundary — there
        is no model to re-fetch; the party's bottom stays local. If the
        fleet had emptied out entirely, the join restarts the cadence."""
        if self._idle and not sched.finished:
            self._empty_rounds = 0
            self._begin_round(sched, now)
