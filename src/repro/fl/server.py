"""FL server: round orchestration over any CommBackend, with concurrent
dispatch, quorum/deadline straggler mitigation, fault handling and the
paper's per-state time accounting (Fig 5: communication / migration /
serialization / waiting / training / aggregation).

All timing below is simulated-clock seconds from netsim; payload movement
is real whenever payloads are real.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.backends.base import CommBackend
from repro.core.message import (FLMessage, TensorPayload, VirtualPayload)
from repro.core.netsim import Region, Transfer, simulate_transfers
from repro.fl.aggregator import fedavg, simulated_agg_time
from repro.fl.client import PCIE_BW, ClientTiming, FLClient


@dataclasses.dataclass
class RoundReport:
    round: int
    backend: str
    round_time: float
    server: Dict[str, float]
    clients: Dict[str, float]  # averaged across participating clients
    n_participants: int
    n_dropped: int
    peak_server_memory: int
    aborted: bool = False
    losses: Optional[float] = None


class FLServer:
    def __init__(self, backend, clients: Sequence[FLClient], *,
                 quorum_fraction: float = 1.0, round_deadline_s: float = 0.0,
                 local_steps: int = 10, live: bool = True,
                 checkpoint_mgr=None, server_lr: float = 1.0):
        self.backend = backend
        self.clients = list(clients)
        self.quorum_fraction = quorum_fraction
        self.round_deadline_s = round_deadline_s
        self.local_steps = local_steps
        self.live = live
        self.ckpt = checkpoint_mgr
        self.server_lr = server_lr
        self.now = 0.0
        self.reports: List[RoundReport] = []
        self.global_params = None
        self.round = 0

    # ------------------------------------------------------------------
    def _client_backend(self, client: FLClient, msg=None):
        cb = client.backend
        if msg is not None and hasattr(cb, "resolve"):
            return cb.resolve(msg)  # AUTO: plan with the routed backend
        return cb

    def _upload_phase(self, sends):
        """sends: list of (client, update_msg, start_t). Contention-aware
        upload of all updates; returns dict client_id -> (arrive_t, ser_s)."""
        out = {}
        backend = self.backend
        name = getattr(backend, "name", "grpc")
        # AUTO plans the upload leg with whatever backend it would route
        # the first update onto — resolve() sees the post-compression
        # wire size, so a compressed large update correctly plans gRPC
        use_s3 = name == "grpc+s3" or (
            name == "auto" and sends
            and backend.resolve(sends[0][1]) is backend.s3)
        fm = backend.fabric.fault_model
        if use_s3:
            from repro.core.channel import encode_many
            s3 = backend if name == "grpc+s3" else backend.s3
            cbs = [self._client_backend(client, msg)
                   for client, msg, _ in sends]
            # store exactly what each client's wire stack produces and
            # charge those bytes (a compressing channel stores the
            # smaller wire); virtual paper-scale payloads keep their
            # nominal size. All clients' encodes go through one fused
            # batch — one quantize kernel dispatch for the whole round
            enc_idx = [i for i, (_, msg, _) in enumerate(sends)
                       if isinstance(msg.payload, TensorPayload)]
            fused = encode_many([(cbs[i].channel, sends[i][1].payload, "s3")
                                 for i in enc_idx])
            encs = [None] * len(sends)
            for i, enc in zip(enc_idx, fused):
                encs[i] = enc
            transfers, meta = [], []
            for (client, msg, start), cb, enc in zip(sends, cbs, encs):
                wire = enc.wire if enc is not None else None
                nbytes = wire.nbytes if wire is not None \
                    else msg.payload_nbytes
                ser = (enc.cost_s if enc is not None
                       else cb.serializer.ser_time(msg.payload_nbytes))
                src = cb.env.host(client.client_id)
                put = s3.store.put_time(nbytes, src, s3.parts)
                key = s3.store.content_key(
                    (msg.payload.fingerprint(), cb.channel.signature()),
                    msg.round, client.client_id)
                # BlackoutSpec contract, as on the isend path: the PUT
                # holds while the client host is dark, the meta record
                # while its edge to the hub is (no-op with no windows)
                t_put = start + ser
                if fm is not None:
                    t_put = fm.delay((client.client_id,), t_put)
                t_meta = t_put + put
                if fm is not None:
                    t_meta = fm.delay((client.client_id, "server"), t_meta)
                s3.store.put(key, wire, nbytes, t_put + put)
                region = cb._link_region("server")
                meta_arrive = t_meta + cb._overhead(region) \
                    + region.latency
                dst = s3.env.host("server")
                tr = s3.store.get_transfer(key, dst, meta_arrive, s3.parts)
                transfers.append(tr)
                meta.append((client, msg, ser, key, wire))
            simulate_transfers(transfers)
            for (client, msg, ser, key, wire), tr in zip(meta, transfers):
                deser = (s3.channel.decode_time(wire) if wire is not None
                         else s3.serializer.deser_time(msg.payload_nbytes))
                out[client.client_id] = (tr.finish + deser, ser, msg, key)
                s3.fabric.account(tr.nbytes)
            return out
        # direct backends: concurrent client->server transfers
        transfers, meta = [], []
        for client, msg, start in sends:
            cb = self._client_backend(client, msg)
            ser = cb.serializer.ser_time(msg.payload_nbytes)
            region = cb._link_region("server")
            dep = start + ser + cb._overhead(region)
            if fm is not None:
                # blackout-shifted departure, as on the isend path
                # (no-op with no windows installed)
                dep = fm.delay((client.client_id, "server"), dep)
            transfers.append(Transfer(
                start=dep,
                src=cb.env.host(client.client_id),
                dst=cb.env.host("server"),
                nbytes=msg.payload_nbytes,
                conns=cb.policy.conns_per_transfer,
                link_region=region, tag=client.client_id))
            meta.append((client, msg, ser))
        simulate_transfers(transfers)
        for (client, msg, ser), tr in zip(meta, transfers):
            sb = self.backend
            if hasattr(sb, "resolve"):
                sb = sb.resolve(msg)
            deser = sb.serializer.deser_time(msg.payload_nbytes)
            out[client.client_id] = (tr.finish + deser, ser, msg, None)
            sb.fabric.account(tr.nbytes)
        return out

    # ------------------------------------------------------------------
    def run_round(self, global_payload, *, dropped: Optional[set] = None,
                  participants: Optional[Sequence[FLClient]] = None):
        """One FL round. ``global_payload``: TensorPayload | VirtualPayload.
        Returns RoundReport (and updates self.global_params in live mode)."""
        dropped = dropped or set()
        clients = list(participants or self.clients)
        t0 = self.now
        self.backend.endpoint.memory.reset()

        # 1) concurrent broadcast of the global model
        msgs = [FLMessage("model_sync", "server", c.client_id,
                          round=self.round, payload=global_payload)
                for c in clients]
        sender_done, _ = self.backend.broadcast(msgs, t0)

        # 2) clients receive, train, stage updates
        sends, timings = [], {}
        for c in clients:
            cb = self._client_backend(c)
            got = cb.recv(t0 + 1e9)  # pop whatever was scheduled
            if not got:
                continue
            msg, ready = got[0]
            if c.client_id in dropped:
                timings[c.client_id] = ClientTiming(
                    communication=ready - t0)
                continue
            update, ct, send_start = c.run_round(msg, ready, self.local_steps)
            ct.communication += ready - t0
            sends.append((c, update, send_start))
            timings[c.client_id] = ct

        aborted = False
        if dropped and _is_mpi(self.backend):
            # MPI's static world: a lost rank aborts the round (paper §II-C);
            # restart costs a checkpoint restore + full re-run marker.
            aborted = True

        # 3) contention-aware concurrent uploads
        arrivals = self._upload_phase(sends)

        # 4) quorum / deadline aggregation
        ready_sorted = sorted((v[0], cid) for cid, v in arrivals.items())
        cutoff_t, counted, late = quorum_cutoff(
            ready_sorted, len(clients), self.quorum_fraction,
            self.round_deadline_s, t0)

        # 5) aggregate
        updates, weights = [], []
        ser_s = 0.0
        for cid in counted:
            at, ser, msg, _ = arrivals[cid]
            ser_s += ser
            if isinstance(msg.payload, TensorPayload):
                updates.append(msg.payload.tree)
                weights.append(msg.metadata.get("num_examples", 1))
        if updates:
            agg, agg_s = fedavg(updates, weights)
            self.global_params = agg
            mig_s = 2 * global_payload.nbytes / PCIE_BW
        else:
            agg_s = simulated_agg_time(global_payload.nbytes, len(counted))
            mig_s = 2 * global_payload.nbytes / PCIE_BW
        agg_done = cutoff_t + mig_s + agg_s
        self.now = agg_done
        self.round += 1

        # 6) per-state report (paper Fig 5)
        cl_avg = _avg_timings([timings[cid] for cid in counted
                               if cid in timings], arrivals, agg_done)
        server_states = {
            "communication": (sender_done - t0) + _server_comm(arrivals,
                                                               counted),
            "migration": mig_s,
            "serialization": ser_s / max(len(counted), 1),
            "waiting": max(cutoff_t - sender_done, 0.0),
            "aggregation": agg_s,
        }
        losses = [getattr(c, "last_loss", None) for c in clients]
        losses = [l for l in losses if l is not None]
        report = RoundReport(
            round=self.round - 1, backend=getattr(self.backend, "name", "?"),
            round_time=agg_done - t0, server=server_states, clients=cl_avg,
            n_participants=len(counted), n_dropped=len(dropped) + len(late),
            peak_server_memory=self.backend.endpoint.memory.peak,
            aborted=aborted,
            losses=float(np.mean(losses)) if losses else None)
        self.reports.append(report)
        if self.ckpt is not None and self.global_params is not None:
            self.ckpt.save(self.round, self.global_params,
                           meta={"sim_time": self.now})
        return report


    # ------------------------------------------------------------------
    def run_async(self, global_payload, strategy, *, availability=None,
                  cohort_k: int = 0, cohort_seed: int = 0,
                  streaming_hub: bool = False, **limits):
        """Event-driven execution of this deployment (fl/scheduler.py):
        same backend + clients, but the strategy decides when to merge.
        ``availability``: optional fl/fault.AvailabilityTrace replayed as
        join/leave loop events; ``cohort_k``/``streaming_hub``: the
        fleet-scale knobs, passed through to the scheduler.
        Returns (AsyncRunReport, FLScheduler)."""
        from repro.fl.scheduler import FLScheduler
        sched = FLScheduler(self.backend, self.clients, strategy,
                            local_steps=self.local_steps,
                            server_lr=self.server_lr,
                            availability=availability,
                            cohort_k=cohort_k, cohort_seed=cohort_seed,
                            streaming_hub=streaming_hub)
        report = sched.run(global_payload, **limits)
        if sched.global_params is not None:
            self.global_params = sched.global_params
        self.now = sched.loop.now
        return report, sched


def quorum_cutoff(ready_sorted, n_expected: int, quorum_fraction: float,
                  round_deadline_s: float, t0: float):
    """Shared quorum/deadline policy: when does a sync(-ish) round close,
    who made it, who is late. ``ready_sorted``: sorted (arrive_t, cid)."""
    ready_sorted = list(ready_sorted)
    need = max(1, int(np.ceil(quorum_fraction * n_expected)))
    need = min(need, len(ready_sorted))
    cutoff_t = ready_sorted[need - 1][0] if ready_sorted else t0
    if round_deadline_s:
        cutoff_t = min(cutoff_t, t0 + round_deadline_s)
    counted = [cid for (at, cid) in ready_sorted if at <= cutoff_t + 1e-9]
    late = [cid for (at, cid) in ready_sorted if at > cutoff_t + 1e-9]
    return cutoff_t, counted, late


def _is_mpi(backend) -> bool:
    return getattr(backend, "name", "").startswith("mpi")


def _server_comm(arrivals, counted) -> float:
    """Server-side receive span (first byte to last counted update)."""
    if not counted:
        return 0.0
    ts = [arrivals[cid][0] for cid in counted]
    return max(ts) - min(ts) if len(ts) > 1 else 0.0


def _avg_timings(timings: List[ClientTiming], arrivals, round_end) -> Dict[str, float]:
    if not timings:
        return {k: 0.0 for k in ("communication", "migration",
                                 "serialization", "waiting", "training")}
    out = {
        "communication": float(np.mean([t.communication for t in timings])),
        "migration": float(np.mean([t.migration for t in timings])),
        "serialization": float(np.mean([t.serialization for t in timings])),
        "training": float(np.mean([t.training for t in timings])),
    }
    waits = []
    for cid, (at, ser, msg, _) in arrivals.items():
        waits.append(max(round_end - at, 0.0))
    out["waiting"] = float(np.mean(waits)) if waits else 0.0
    # fold upload serialization into the client's serialization state
    sers = [arrivals[cid][1] for cid in arrivals]
    out["serialization"] += float(np.mean(sers)) if sers else 0.0
    return out
