"""Failure & straggler injection + recovery policies.

The paper's fault-tolerance claims exercised here:
* gRPC/gRPC+S3: dynamic participation — dropped clients are simply not
  counted (quorum), late clients re-fetch the current model from S3 with no
  sender involvement.
* MPI: static world — a lost rank aborts the round; recovery = restore the
  last checkpoint and re-run the round (cost modelled + measured).

Two injection granularities:
* ``FaultPlan``         — per-round Bernoulli drop/straggler draws for the
  synchronous loop (``FLServer.run_round``). Drop and straggler are
  *independent* draws from split seeded streams, so each marginal rate is
  exactly its knob (a coupled ``elif`` draw would skew the straggler rate
  to ``(1-drop)*straggler`` and correlate the two).
* ``AvailabilityTrace`` — client join/leave/rejoin events at arbitrary
  simulated times, consumed by the event-driven scheduler
  (``fl/scheduler.py``) as first-class events. This is the churn model
  the async strategies are tested against: mid-round departures, relay
  quorum, S3 late-join re-fetch.

Link-level faults (chunk loss, blackouts) live in
``core/netsim.LinkFaultModel`` and are injected by the transport fabric.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np


@dataclasses.dataclass
class FaultPlan:
    drop_rate: float = 0.0  # per client per round
    straggler_rate: float = 0.0  # fraction of clients slowed
    straggler_factor: float = 3.0
    seed: int = 0

    def for_round(self, round_: int, client_ids) -> tuple:
        """(dropped, stragglers) for one round. The two sets come from
        independent split-seeded streams: a client can be both, and each
        marginal rate equals its knob (regression-tested)."""
        rng_drop = np.random.default_rng((self.seed, round_, 0))
        rng_strag = np.random.default_rng((self.seed, round_, 1))
        dropped: Set[str] = set()
        stragglers: Set[str] = set()
        for cid in client_ids:
            if rng_drop.random() < self.drop_rate:
                dropped.add(cid)
            if rng_strag.random() < self.straggler_rate:
                stragglers.add(cid)
        return dropped, stragglers


def apply_stragglers(clients, stragglers, factor: float):
    for c in clients:
        c.straggle_factor = factor if c.client_id in stragglers else 1.0


# ---------------------------------------------------------------------------
# availability traces (event-driven churn)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AvailabilityEvent:
    time: float
    client_id: str
    kind: str  # "leave" | "join"


class AvailabilityTrace:
    """A timeline of client churn events. Every client starts *up*;
    ``leave``/``join`` events toggle it. The scheduler replays the trace
    as loop events; strategies decide what a departure mid-round means
    (fedbuff/semisync discard in-flight updates from departed clients,
    hier re-checks its relay quorum)."""

    def __init__(self, events: Iterable[AvailabilityEvent] = ()):
        self.events: List[AvailabilityEvent] = sorted(
            events, key=lambda e: (e.time, e.client_id, e.kind))

    def __len__(self) -> int:
        return len(self.events)

    def for_client(self, client_id: str) -> List[AvailabilityEvent]:
        return [e for e in self.events if e.client_id == client_id]

    def is_up(self, client_id: str, t: float) -> bool:
        up = True
        for e in self.events:
            if e.time > t:
                break
            if e.client_id == client_id:
                up = e.kind == "join"
        return up

    # -- constructors ----------------------------------------------------
    @classmethod
    def parse(cls, spec: str) -> "AvailabilityTrace":
        """``"client0:leave@120,join@400;client3:leave@50"`` — explicit
        per-client event lists (the ``fl_train --availability-trace``
        format)."""
        events = []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            cid, _, evs = part.partition(":")
            if not evs:
                raise ValueError(
                    f"availability spec '{part}': want 'client:kind@t,...'")
            for ev in filter(None, (e.strip() for e in evs.split(","))):
                kind, _, t = ev.partition("@")
                if kind not in ("leave", "join") or not t:
                    raise ValueError(
                        f"availability event '{ev}': want leave@T or join@T")
                events.append(AvailabilityEvent(float(t), cid.strip(), kind))
        return cls(events)

    @classmethod
    def generate(cls, client_ids: Sequence[str], horizon_s: float, *,
                 mean_up_s: float, mean_down_s: float,
                 seed: int = 0) -> "AvailabilityTrace":
        """Alternating exponential up/down periods per client, each
        client on its own stream keyed by its *id* (adding or removing a
        client never reshuffles another's trace)."""
        import zlib
        events = []
        for cid in sorted(client_ids):
            rng = np.random.default_rng((seed, 0x5EED,
                                         zlib.crc32(cid.encode())))
            t = rng.exponential(mean_up_s)
            while t < horizon_s:
                events.append(AvailabilityEvent(float(t), cid, "leave"))
                t += rng.exponential(mean_down_s)
                if t >= horizon_s:
                    break
                events.append(AvailabilityEvent(float(t), cid, "join"))
                t += rng.exponential(mean_up_s)
        return cls(events)


def make_availability(spec: str, client_ids: Sequence[str],
                      horizon_s: float,
                      seed: int = 0) -> Optional[AvailabilityTrace]:
    """CLI adapter: '' -> None; 'auto:MEAN_UP/MEAN_DOWN' -> generated
    trace over ``horizon_s``; anything else -> ``AvailabilityTrace.parse``."""
    if not spec:
        return None
    if spec.startswith("auto:"):
        up, _, down = spec[len("auto:"):].partition("/")
        return AvailabilityTrace.generate(
            client_ids, horizon_s, mean_up_s=float(up),
            mean_down_s=float(down) if down else float(up), seed=seed)
    return AvailabilityTrace.parse(spec)


# ---------------------------------------------------------------------------
# recovery cost models
# ---------------------------------------------------------------------------

def mpi_abort_recovery_time(ckpt_restore_s: float, round_time_s: float) -> float:
    """Paper §II-C: MPI failure handling lacks fault isolation — global
    abort, restore, re-run."""
    return ckpt_restore_s + round_time_s


def s3_late_join_time(store, key: str, host, now: float) -> float:
    """A restarted client pulls the current global model directly from the
    object store (single-upload/multi-download durability)."""
    obj, attempts = store.get(key)
    return now + attempts * store.get_time(obj.nbytes, host)
