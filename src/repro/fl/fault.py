"""Failure & straggler injection + recovery policies.

The paper's fault-tolerance claims exercised here:
* gRPC/gRPC+S3: dynamic participation — dropped clients are simply not
  counted (quorum), late clients re-fetch the current model from S3 with no
  sender involvement.
* MPI: static world — a lost rank aborts the round; recovery = restore the
  last checkpoint and re-run the round (cost modelled + measured).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Set

import numpy as np


@dataclasses.dataclass
class FaultPlan:
    drop_rate: float = 0.0  # per client per round
    straggler_rate: float = 0.0  # fraction of clients slowed
    straggler_factor: float = 3.0
    seed: int = 0

    def for_round(self, round_: int, client_ids) -> tuple:
        rng = np.random.default_rng(self.seed * 7919 + round_)
        dropped: Set[str] = set()
        stragglers: Set[str] = set()
        for cid in client_ids:
            if rng.random() < self.drop_rate:
                dropped.add(cid)
            elif rng.random() < self.straggler_rate:
                stragglers.add(cid)
        return dropped, stragglers


def apply_stragglers(clients, stragglers, factor: float):
    for c in clients:
        c.straggle_factor = factor if c.client_id in stragglers else 1.0


def mpi_abort_recovery_time(ckpt_restore_s: float, round_time_s: float) -> float:
    """Paper §II-C: MPI failure handling lacks fault isolation — global
    abort, restore, re-run."""
    return ckpt_restore_s + round_time_s


def s3_late_join_time(store, key: str, host, now: float) -> float:
    """A restarted client pulls the current global model directly from the
    object store (single-upload/multi-download durability)."""
    obj, attempts = store.get(key)
    return now + attempts * store.get_time(obj.nbytes, host)
