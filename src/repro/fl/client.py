"""FL client: local training + timing breakdown.

Two compute modes:
* live       — real jit'd local SGD on the client's silo shard (tests,
               examples, small tiers);
* simulated  — training time charged from the tier's calibrated
               per-round seconds (paper-scale Fig 5 runs with virtual
               payloads).

Migration = host<->accelerator staging of the payload (the paper's
'CPU-GPU migration' state); charged at PCIe-class bandwidth, or measured
when live.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.message import FLMessage, TensorPayload, VirtualPayload

PCIE_BW = 12e9  # bytes/s host<->device staging


@dataclasses.dataclass
class ClientTiming:
    communication: float = 0.0
    migration: float = 0.0
    serialization: float = 0.0
    waiting: float = 0.0
    training: float = 0.0


class FLClient:
    def __init__(self, client_id: str, backend, *, dataset=None,
                 train_fn: Optional[Callable] = None,
                 sim_train_s: float = 0.0, batch_size: int = 16,
                 straggle_factor: float = 1.0, seed: int = 0):
        """train_fn(params, batch) -> (new_params, loss) — jit'd by caller.

        ``sim_train_s`` > 0 with a live ``train_fn`` trains for real but
        charges the calibrated time instead of measured wall seconds —
        "live compute, simulated clock", which keeps event-driven runs
        deterministic (jit compile jitter never leaks into the sim)."""
        self.client_id = client_id
        self.backend = backend
        self.dataset = dataset
        self.train_fn = train_fn
        self.sim_train_s = sim_train_s
        self.batch_size = batch_size
        self.straggle_factor = straggle_factor
        self.seed = seed
        self._round = 0
        self._sends = 0  # distinct virtual updates must not alias in the
        # object store's content-addressed cache (each round re-uploads)

    # ------------------------------------------------------------------
    def local_train(self, params, local_steps: int):
        """Live local training. Returns (new_params, mean_loss, seconds)."""
        t0 = time.perf_counter()
        it = self.dataset.batches(self.batch_size, seed=self.seed + self._round)
        losses = []
        for _ in range(local_steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, loss = self.train_fn(params, batch)
            losses.append(float(loss))
        jax.block_until_ready(jax.tree.leaves(params)[0])
        return params, float(np.mean(losses)), time.perf_counter() - t0

    # ------------------------------------------------------------------
    def run_round(self, msg: FLMessage, ready_t: float, local_steps: int,
                  server_id: str = "server"):
        """Handle one received global model; returns (update_msg, timing,
        send_start_t). Works in live or simulated mode depending on the
        payload type."""
        self._round = msg.round
        timing = ClientTiming()
        payload = msg.payload
        nbytes = payload.nbytes
        # host -> device staging
        mig_in = nbytes / PCIE_BW
        timing.migration += mig_in
        t = ready_t + mig_in

        if isinstance(payload, VirtualPayload) or self.train_fn is None:
            train_s = self.sim_train_s * self.straggle_factor
            self._sends += 1
            update_payload = VirtualPayload(
                nbytes, tag=f"upd:{self.client_id}:{self._sends}")
            num_examples = 128
        else:
            new_params, loss, train_s = self.local_train(payload.tree,
                                                         local_steps)
            if self.sim_train_s > 0:
                train_s = self.sim_train_s  # live compute, simulated clock
            train_s *= self.straggle_factor
            update_payload = TensorPayload(new_params)
            num_examples = self.dataset.num_examples()
            self.last_loss = loss
        timing.training += train_s
        t += train_s
        # device -> host staging of the update
        mig_out = update_payload.nbytes / PCIE_BW
        timing.migration += mig_out
        t += mig_out
        update = FLMessage("client_update", self.client_id, server_id,
                           round=msg.round, payload=update_payload,
                           metadata={"num_examples": num_examples,
                                     # global version this update was
                                     # trained against (async staleness)
                                     "version": msg.metadata.get(
                                         "version", msg.round)})
        return update, timing, t
