"""Server-side aggregation — FedAvg on the Pallas reduction kernels.

``fedavg``            — weighted average of client pytrees.
``fedavg_quantized``  — aggregates int8 client payloads with fused
                        dequant+reduce (never materialises f32 copies).
Aggregation compute time is measured for the Fig 5 'aggregation' bars.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.kernels import ops


def fedavg(updates: Sequence, weights, *, interpret=None):
    """updates: list of pytrees; weights ~ num_examples per client."""
    t0 = time.perf_counter()
    agg = ops.fedavg_aggregate(updates, weights, interpret=interpret)
    agg = jax.block_until_ready(agg)
    return agg, time.perf_counter() - t0


def fedavg_quantized(packed_list: Sequence[dict], weights, unflatten, *,
                     interpret=None):
    t0 = time.perf_counter()
    agg = ops.fedavg_aggregate_q8(packed_list, weights, unflatten,
                                  interpret=interpret)
    agg = jax.block_until_ready(agg)
    return agg, time.perf_counter() - t0


def simulated_agg_time(nbytes: int, n_clients: int,
                       hbm_bw: float = 400e9) -> float:
    """Aggregation is bandwidth-bound: read N updates + write one
    (used when payloads are virtual)."""
    return (n_clients + 1) * nbytes / hbm_bw
