"""Server-side aggregation — FedAvg on the Pallas reduction kernels.

``fedavg``            — weighted average of client pytrees.
``fedavg_quantized``  — aggregates int8 client payloads with fused
                        dequant+reduce (never materialises f32 copies).
``StreamingAccumulator`` — O(model) running fold for the fleet-scale hub
                        (one ``acc += eff * update`` per arrival instead
                        of buffering O(clients) update trees).
``staleness_weight``  — FedBuff-style polynomial discount for async modes.
``merge_global``      — staleness-damped server update (event-driven modes).
Aggregation compute time is measured for the Fig 5 'aggregation' bars.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.kernels import ops


def fedavg(updates: Sequence, weights, *, interpret=None):
    """updates: list of pytrees; weights ~ num_examples per client."""
    t0 = time.perf_counter()
    agg = ops.fedavg_aggregate(updates, weights, interpret=interpret)
    agg = jax.block_until_ready(agg)
    return agg, time.perf_counter() - t0


def fedavg_quantized(packed_list: Sequence[dict], weights, unflatten, *,
                     interpret=None):
    t0 = time.perf_counter()
    agg = ops.fedavg_aggregate_q8(packed_list, weights, unflatten,
                                  interpret=interpret)
    agg = jax.block_until_ready(agg)
    return agg, time.perf_counter() - t0


class StreamingAccumulator:
    """O(model) streaming replacement for the hub's dense update buffer.

    ``fold`` adds one effective-weight-scaled update into a flat f32
    running sum (``ops.fedavg_accumulate_flat`` — the fedavg_reduce
    streaming-accumulate kernel path); ``merged`` divides by the summed
    effective weight, which equals the dense ``fedavg(trees, eff)``
    normalised average within float tolerance (tested). Virtual payloads
    fold as bookkeeping only (count / weight sums), so paper-scale runs
    keep their analytic merge timing.
    """

    def __init__(self):
        self.acc = None  # flat f32 running sum of eff-weighted updates
        self.unflatten = None
        self.sum_eff = 0.0
        self.sum_weight = 0.0
        self.count = 0  # client updates folded (records' ``count`` sum)
        self.agg_s = 0.0  # accumulated fold compute seconds

    def fold(self, rec, alpha: float, *, interpret=None):
        """rec: scheduler UpdateRecord; alpha: its staleness discount."""
        from repro.core.message import TensorPayload
        eff = rec.weight * float(alpha)
        self.sum_eff += eff
        self.sum_weight += rec.weight
        self.count += rec.count
        if isinstance(rec.payload, TensorPayload):
            t0 = time.perf_counter()
            flat, unflatten = ops.flatten_pytree(rec.payload.tree)
            if self.acc is None:
                self.unflatten = unflatten
                self.acc = ops.fedavg_accumulate_flat(
                    np.zeros(flat.shape[0], np.float32), flat, eff,
                    interpret=interpret)
            else:
                self.acc = ops.fedavg_accumulate_flat(
                    self.acc, flat, eff, interpret=interpret)
            jax.block_until_ready(self.acc)
            self.agg_s += time.perf_counter() - t0

    def merged(self):
        """-> (merged pytree | None, measured agg seconds)."""
        if self.acc is None or self.sum_eff <= 0:
            return None, self.agg_s
        t0 = time.perf_counter()
        tree = self.unflatten(self.acc / np.float32(self.sum_eff))
        tree = jax.block_until_ready(tree)
        return tree, self.agg_s + time.perf_counter() - t0

    def reset(self):
        self.acc = None
        self.unflatten = None
        self.sum_eff = 0.0
        self.sum_weight = 0.0
        self.count = 0
        self.agg_s = 0.0


def simulated_agg_time(nbytes: int, n_clients: int,
                       hbm_bw: float = 400e9) -> float:
    """Aggregation is bandwidth-bound: read N updates + write one
    (used when payloads are virtual)."""
    return (n_clients + 1) * nbytes / hbm_bw


def staleness_weight(staleness: float, exponent: float = 0.5) -> float:
    """FedBuff-style polynomial staleness discount ``(1 + s)^-a``.

    ``s`` is how many global versions elapsed between the model a client
    trained on and the one it is merged into; ``a = 0`` disables the
    discount (every update counts fully, the sync-FedAvg limit)."""
    return (1.0 + max(float(staleness), 0.0)) ** (-exponent)


def merge_global(global_tree, merged_tree, lam: float):
    """Damped server update: ``(1 - lam) * global + lam * merged``.

    ``lam = server_lr * (effective weight / raw weight)`` — a buffer of
    fresh updates (lam -> 1) replaces the global model exactly like sync
    FedAvg; a stale-heavy buffer moves it proportionally less."""
    lam = min(max(lam, 0.0), 1.0)
    if global_tree is None or lam >= 1.0 - 1e-12:
        return merged_tree
    return jax.tree.map(lambda g, m: (1.0 - lam) * g + lam * m,
                        global_tree, merged_tree)
