"""Co-scheduling driver: N FLScheduler jobs on ONE shared EventLoop.

Each tenant job is a normal ``FLScheduler`` constructed with
``loop=shared_loop`` and a backend bound to a ``Fabric.job(...)``
handle; the driver bootstraps every job via ``scheduler.prepare``
(respecting per-job ``start_s`` offsets), runs the single clock once,
and stops it when the last job reports finished.  A finished job
quiesces — its timer/dispatch callbacks early-return on the
``finished`` flag — instead of stopping the loop, so the surviving
tenants keep the clock (and the contended links) to themselves.

Jobs interleave on the simulated clock but contend only through the
fabric: when ``FabricSpec.shared_links`` is on, flows from different
jobs traversing the same declared edge share one pipe under the
fabric's admission policy (fifo / priority / fair-share).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.fl.scheduler import AsyncRunReport, EventLoop, FLScheduler


class MultiScheduler:
    """Drive several FLScheduler jobs on one shared EventLoop.

    Usage::

        loop = EventLoop()
        fabric = Fabric(env, spec=FabricSpec(policy="priority",
                                             shared_links=True))
        job_a = fabric.job("a", priority=1)
        ... build FLScheduler(..., loop=loop) per job ...
        multi = MultiScheduler(loop)
        multi.add_job("a", sched_a, payload_a, max_aggregations=20)
        multi.add_job("b", sched_b, payload_b, max_aggregations=20,
                      start_s=30.0)
        reports = multi.run()          # {"a": AsyncRunReport, ...}
    """

    def __init__(self, loop: EventLoop):
        self.loop = loop
        self.jobs: Dict[str, FLScheduler] = {}
        self._prepared: List[tuple] = []  # (name, payload, kwargs)
        self._running = 0

    def add_job(self, name: str, scheduler: FLScheduler, global_payload, *,
                max_aggregations: Optional[int] = None,
                target_effective_updates: Optional[float] = None,
                start_s: float = 0.0) -> None:
        if name in self.jobs:
            raise ValueError(f"duplicate job name {name!r}")
        if scheduler.loop is not self.loop:
            raise ValueError(
                f"job {name!r}: scheduler was not built on this shared loop "
                "(pass loop= to FLScheduler)")
        if max_aggregations is None and target_effective_updates is None:
            raise ValueError(
                f"job {name!r} needs a cap: max_aggregations= or "
                "target_effective_updates= (a capless tenant would never "
                "quiesce and the shared clock would run to until=)")
        self.jobs[name] = scheduler
        self._prepared.append((name, global_payload, dict(
            max_aggregations=max_aggregations,
            target_effective_updates=target_effective_updates,
            start_s=start_s)))

    # ------------------------------------------------------------------
    def _on_job_finished(self, sched: FLScheduler, done_t: float) -> None:
        self._running -= 1
        if self._running <= 0:
            self.loop.stop()

    def run(self, until: float = math.inf) -> Dict[str, AsyncRunReport]:
        if not self._prepared:
            raise ValueError("no jobs added")
        self._running = len(self._prepared)
        for name, payload, kw in self._prepared:
            sched = self.jobs[name]
            sched.on_finished = self._on_job_finished

            def boot(now, *, _s=sched, _p=payload, _kw=kw):
                _s.prepare(_p, **_kw)

            # bootstrap through the loop, NOT synchronously: a job's
            # round-0 broadcast reserves shared pipes, so it must solve
            # in simulated-time order (a t=0 tenant before a t=30 one),
            # not in add_job order
            self.loop.call_at(kw["start_s"], f"job-start:{name}", boot)
        self.loop.run(until=until)
        return {name: self.jobs[name].report() for name in self.jobs}
