"""Event-driven FL runtime — clients driven independently, not in lockstep.

The paper's Fig 5 loop (``FLServer.run_round``) models synchronous rounds:
every client trains on the same global version and the server blocks on a
quorum. The interesting scale regime — stragglers, WAN heterogeneity,
throughput-optimal topologies (Marfoq et al.) — is asynchronous. This
module provides that runtime:

* ``EventLoop``    — deterministic discrete-event queue over the simulated
  clock. Events are ordered by ``(time, insertion seq)`` so ties resolve in
  schedule order and replaying the same deployment reproduces the exact
  same trace (tested).
* ``FLScheduler``  — drives each ``FLClient`` through its own
  dispatch -> train -> upload pipeline using the backends' non-blocking
  ``isend`` handles and inbox polling (``recv`` / ``next_arrival``), and
  delegates *when and how to aggregate* to a pluggable strategy
  (fl/async_strategies.py): FedBuff-style buffered async, semi-synchronous
  quorum+deadline, or hierarchical per-region relays.

Payload movement is real whenever payloads are real (TensorPayload trees
travel through the same serializers/fabric as the sync path); time is
simulated-clock seconds from netsim either way.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.message import FLMessage, TensorPayload, VirtualPayload
from repro.fl.aggregator import (fedavg, merge_global, simulated_agg_time,
                                 staleness_weight)
from repro.fl.client import PCIE_BW, FLClient


@dataclasses.dataclass
class UpdateRecord:
    """One client (or relay) update as seen by the aggregation strategy."""
    client: Optional[FLClient]
    payload: Any  # TensorPayload | VirtualPayload | PackedPayload
    weight: float  # num_examples (or summed, for relay partials)
    version: int  # global version the update was trained against
    staleness: int  # server version delta at merge decision time
    arrive_t: float
    count: int = 1  # client updates folded in (relay partials carry many)


@dataclasses.dataclass
class AggregationEvent:
    time: float
    version: int
    n_updates: int
    mean_staleness: float
    effective_weight: float  # sum of staleness discounts alpha(s)
    loss: Optional[float] = None


@dataclasses.dataclass
class AsyncRunReport:
    """What one event-driven run produced (the fig6 results surface)."""
    mode: str
    backend: str
    sim_time: float
    n_aggregations: int
    n_client_updates: int
    effective_updates: float
    mean_staleness: float
    aggregations_per_hour: float
    client_updates_per_hour: float
    time_to_target: Optional[float]
    final_loss: Optional[float]
    n_discarded: int
    n_events: int
    # churn / fault accounting (0 unless an AvailabilityTrace or a
    # LinkFaultModel is installed)
    n_departures: int = 0
    n_rejoins: int = 0
    n_transfer_failures: int = 0
    n_late_refetches: int = 0


class _CalendarQueue:
    """Calendar-queue bucket structure over ``(time, seq, ...)`` entries.

    Events land in fixed-width time slots keyed ``int(t // width)``; a
    small heap of slot keys (lazy-created, dropped once drained) finds
    the next non-empty slot. ``EventLoop.call_at`` clamps times to
    >= now, so no insert can land before the slot currently draining and
    the cursor advances monotonically. A slot's list is heapified once
    when it becomes current; same-slot inserts after that heap-push.

    Pop order is exactly the flat heap's global ``(time, seq)`` order:
    slots partition the time axis and within a slot the heap orders by
    ``(time, seq)`` — so the two queue disciplines produce bit-identical
    traces (tested, and asserted by benchmarks/fig11_scale.py).

    The win over one big heap is batch behaviour at fleet scale: a
    broadcast wave inserts thousands of arrivals into a handful of
    future slots as plain appends (O(1) each, no sift through the events
    of every other slot), and only the slot being drained pays heap
    discipline.
    """

    def __init__(self, width: float = 1.0):
        self.width = float(width)
        self._buckets: dict = {}  # slot key -> event list (heap if current)
        self._keys: list = []  # min-heap of pending slot keys
        self._cur: Optional[int] = None  # slot currently draining
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def push(self, item) -> None:
        key = int(item[0] // self.width)
        bucket = self._buckets.get(key)
        if bucket is None:
            bucket = self._buckets[key] = []
            heapq.heappush(self._keys, key)
        if key == self._cur:
            heapq.heappush(bucket, item)
        else:
            bucket.append(item)
        self._n += 1

    def _front(self):
        """The current slot's heap, advancing past drained slots."""
        while True:
            if self._cur is not None:
                bucket = self._buckets.get(self._cur)
                if bucket and self._keys and self._keys[0] < self._cur:
                    # an earlier slot appeared (a push after a bounded
                    # run(until) clamped to an older now): re-queue the
                    # current slot and re-select the true minimum
                    heapq.heappush(self._keys, self._cur)
                    self._cur = None
                    continue
                if bucket:
                    return bucket
                self._buckets.pop(self._cur, None)
                self._cur = None
            if not self._keys:
                return None
            key = heapq.heappop(self._keys)
            bucket = self._buckets.get(key)
            if not bucket:
                self._buckets.pop(key, None)
                continue
            heapq.heapify(bucket)
            self._cur = key
            return bucket

    def peek(self):
        bucket = self._front()
        return bucket[0] if bucket else None

    def pop(self):
        item = heapq.heappop(self._front())
        self._n -= 1
        return item


class EventLoop:
    """Deterministic discrete-event loop, (time, seq)-ordered.

    ``queue`` selects the event structure: ``"calendar"`` (default) is
    the bucketed calendar queue the fleet-scale engine runs on;
    ``"heap"`` is the original flat ``heapq`` — kept verbatim as the
    un-vectorized baseline fig11 measures against. Both produce
    bit-identical traces (ties resolve by insertion seq either way)."""

    def __init__(self, queue: str = "calendar"):
        if queue not in ("calendar", "heap"):
            raise ValueError(f"unknown event queue '{queue}' "
                             "(use 'calendar' or 'heap')")
        self.queue = queue
        self._q = [] if queue == "heap" else _CalendarQueue()
        self._seq = 0
        self.now = 0.0
        self.stopped = False
        self.trace: List[tuple] = []  # (time, event name) — determinism probe

    def call_at(self, t: float, name: str, fn: Callable, **kw):
        """Schedule ``fn(now, **kw)``; never earlier than the current time."""
        item = (max(float(t), self.now), self._seq, name, fn, kw)
        self._seq += 1
        if self.queue == "heap":
            heapq.heappush(self._q, item)
        else:
            self._q.push(item)

    def call_at_many(self, events: Sequence[tuple]):
        """Batched insertion of ``(t, name, fn, kw)`` tuples — one call
        per broadcast wave instead of one per client (the calendar queue
        turns these into plain appends on future slots)."""
        for t, name, fn, kw in events:
            self.call_at(t, name, fn, **kw)

    def stop(self):
        self.stopped = True

    def run(self, until: float = math.inf) -> float:
        if self.queue == "heap":
            while self._q and not self.stopped:
                t, _, name, fn, kw = self._q[0]
                if t > until:
                    break
                heapq.heappop(self._q)
                self.now = t
                self.trace.append((round(t, 9), name))
                fn(t, **kw)
            return self.now
        while not self.stopped:
            head = self._q.peek()
            if head is None or head[0] > until:
                break
            t, _, name, fn, kw = self._q.pop()
            self.now = t
            self.trace.append((round(t, 9), name))
            fn(t, **kw)
        return self.now


class FLScheduler:
    """Drives an FL deployment through an EventLoop under a strategy."""

    def __init__(self, backend, clients: Sequence[FLClient], strategy, *,
                 local_steps: int = 10, server_lr: float = 1.0,
                 availability=None, redispatch_backoff_s: float = 30.0,
                 event_queue: str = "calendar", cohort_k: int = 0,
                 cohort_seed: int = 0, streaming_hub: bool = False,
                 loop: Optional[EventLoop] = None):
        self.backend = backend  # server-side CommBackend (or AUTO)
        self.clients = list(clients)
        self.strategy = strategy
        self.local_steps = local_steps
        self.server_lr = server_lr
        self.env = backend.env
        # ``loop``: a shared clock injected by the multi-job driver
        # (fl/multijob.MultiScheduler). Standalone schedulers own a
        # private loop and stop it at their cap — the exact legacy path;
        # co-scheduled jobs must NOT stop the shared clock, so they
        # quiesce through ``finished`` instead and notify ``on_finished``
        self.loop = EventLoop(queue=event_queue) if loop is None else loop
        self._shared_loop = loop is not None
        self.finished = False
        self.finished_at: Optional[float] = None
        self.on_finished: Optional[Callable] = None
        self._start_s = 0.0
        self.version = 0
        self.global_payload = None
        self.global_params = None  # real pytree in live mode
        self.n_aggregations = 0
        self.n_updates_applied = 0
        self.effective_updates = 0.0
        self.discarded = 0
        self.time_to_target: Optional[float] = None
        self.agg_log: List[AggregationEvent] = []
        self.update_log: List[tuple] = []  # (arrive_t, client_id, staleness)
        self._agg_busy_until = 0.0  # server merges are serialized
        self._max_agg: Optional[int] = None
        self._target_eff: Optional[float] = None
        # churn (fl/fault.AvailabilityTrace): clients start up; leave/join
        # events toggle membership as first-class loop events
        self.availability = availability
        self.redispatch_backoff_s = redispatch_backoff_s
        self.available = {c.client_id for c in self.clients}
        # dispatch generation per client: bumped on leave, so a model
        # that was in flight across a leave/rejoin blip is dropped on
        # arrival instead of spawning a second permanent train->upload
        # pipeline next to the rejoin dispatch
        self._gen = {c.client_id: 0 for c in self.clients}
        self.departures = 0
        self.rejoins = 0
        self.transfer_failures = 0
        self.late_refetches = 0
        # fleet-scale client table: O(1) id lookup plus flat NumPy arrays
        # for the per-client flags the hot path filters on (a 10k-client
        # dispatch wave is one boolean mask, not 10k attribute walks)
        self._by_id = {c.client_id: c for c in self.clients}
        self._index = {c.client_id: i for i, c in enumerate(self.clients)}
        n = len(self.clients)
        self._up = np.ones(n, dtype=bool)
        self._busy = np.zeros(n, dtype=bool)  # dispatched, not yet resolved
        self._in_cohort = np.ones(n, dtype=bool)
        # cohort sampling (cross-device regime): 0 < K < N samples K
        # clients per aggregation round; K = 0 or K >= N is the full
        # fleet, bit-for-bit today's behaviour (no mask ever consulted)
        self.cohort_k = int(cohort_k)
        self._cohort_rng = np.random.default_rng(cohort_seed)
        # streaming hub: fold arriving updates into an O(model)
        # accumulator instead of buffering O(clients) payloads
        self.streaming_hub = bool(streaming_hub)
        self._acc = None  # fl/aggregator.StreamingAccumulator, lazily
        self._acc_charged = False  # accumulator memory charged once
        self._charged: Dict[int, int] = {}  # id(rec) -> buffered bytes

    # -- plumbing ----------------------------------------------------------
    def _resolved(self, msg: FLMessage):
        be = self.backend
        return be.resolve(msg) if hasattr(be, "resolve") else be

    def is_up(self, client_id: str) -> bool:
        return client_id in self.available

    # -- cohort sampling ---------------------------------------------------
    @property
    def cohort_active(self) -> bool:
        return 0 < self.cohort_k < len(self.clients)

    def _sample_cohort(self):
        """Seeded sample-K-of-N, drawn once before the run starts and
        re-drawn at each aggregation (version bump)."""
        self._in_cohort[:] = False
        picks = self._cohort_rng.choice(len(self.clients),
                                        size=self.cohort_k, replace=False)
        self._in_cohort[picks] = True

    def eligible_count(self) -> int:
        """Live clients a quorum may count on: the sampled cohort's live
        members under cohort sampling, the whole live fleet otherwise."""
        if not self.cohort_active:
            return len(self.available)
        return int(np.count_nonzero(self._up & self._in_cohort))

    def _cohort_blocked(self, client_id: str) -> bool:
        """Outside the current cohort, or its previous dispatch is still
        unresolved (busy pipelines ride across cohort boundaries)."""
        if not self.cohort_active:
            return False
        i = self._index[client_id]
        return bool(not self._in_cohort[i] or self._busy[i])

    def _mark_busy(self, client_id: str, busy: bool):
        i = self._index.get(client_id)
        if i is not None:
            self._busy[i] = busy

    def _cohort_dispatch(self, now: float):
        """Top up the freshly sampled cohort: dispatch its idle members.
        Busy members keep their in-flight pipelines; their reporters
        re-enter through the strategy's own re-dispatch, which
        ``dispatch`` filters against the new cohort."""
        mask = self._in_cohort & self._up & ~self._busy
        self.dispatch_many([self.clients[i] for i in np.nonzero(mask)[0]],
                           now)

    def timer(self, t: float, name: str, fn: Callable, **kw):
        """Schedule a strategy callback ``fn(scheduler, now, **kw)``.
        A finished co-scheduled job stops rescheduling itself — its
        strategy's round timers must not spin the shared clock forever
        (standalone runs never reach here finished: the loop stopped)."""
        if self.finished:
            return
        self.loop.call_at(t, name, lambda now, **k: fn(self, now, **k), **kw)

    def _track(self, h, name: str, fn: Callable, **kw) -> bool:
        """Schedule the completion callback of one send handle. Returns
        False when the fault model failed the transfer (bounded chunk
        retransmits exhausted) — nothing was delivered, the caller picks
        the recovery (re-dispatch / re-upload / give up)."""
        if getattr(h, "failed", False) or math.isinf(h.inbox_t):
            self.transfer_failures += 1
            return False
        self.loop.call_at(h.inbox_t, name, fn, **kw)
        return True

    # -- client pipeline ---------------------------------------------------
    def _model_msg(self, client: FLClient) -> FLMessage:
        return FLMessage("model_sync", self.backend.host_id,
                         client.client_id, round=self.version,
                         payload=self.global_payload,
                         metadata={"version": self.version})

    def dispatch(self, client: FLClient, now: float, _attempt: int = 0):
        """Send the current global model to one client (non-blocking isend;
        concurrent dispatches interleave on the shared completion path).
        Departed clients are skipped; a fault-failed transfer is re-issued
        after a backoff (the model distribution must survive chunk loss),
        bounded so a fully dead link cannot spin the loop forever."""
        if self.finished or not self.is_up(client.client_id):
            return
        if _attempt == 0 and self._cohort_blocked(client.client_id):
            return  # not sampled this round (or its pipeline is live)
        self._mark_busy(client.client_id, True)
        h = self.backend.isend(self._model_msg(client), now)
        if not self._track(h, f"model>{client.client_id}",
                           self._on_client_recv, client=client,
                           gen=self._gen[client.client_id]):
            if _attempt >= 25:
                self._mark_busy(client.client_id, False)
                return  # link is dead: treat the client as unreachable
            # re-issue once the sender has causally *detected* the
            # failure (h.start = give-up time) plus a backoff
            self.loop.call_at(max(now, h.start) + self.redispatch_backoff_s,
                              f"redispatch>{client.client_id}",
                              lambda t, c=client, a=_attempt:
                              self.dispatch(c, t, a + 1))

    def dispatch_many(self, clients: Sequence[FLClient], now: float):
        """Burst dispatch (round start / round close): rides the backend's
        contention-aware concurrent broadcast — the same fluid model the
        sync server charges — instead of independent analytic isends."""
        if self.finished:
            return
        clients = [c for c in clients if self.is_up(c.client_id)]
        if self.cohort_active:
            clients = [c for c in clients
                       if not self._cohort_blocked(c.client_id)]
        if len(clients) <= 1:
            for c in clients:
                self.dispatch(c, now)
            return
        for c in clients:
            self._mark_busy(c.client_id, True)
        msgs = [self._model_msg(c) for c in clients]
        _, arrives = self.backend.broadcast(msgs, now)
        self.loop.call_at_many(
            [(arrive, f"model>{c.client_id}", self._on_client_recv,
              dict(client=c, gen=self._gen[c.client_id]))
             for c, arrive in zip(clients, arrives)])

    def rejoin(self, client: FLClient, now: float):
        """Late-join re-fetch: over grpc+s3 the dispatch rides the
        content-addressed cache — the rejoining client pulls the current
        model straight from the durable store with *no sender re-upload*
        (the paper's single-upload/multi-download story); direct backends
        pay a full re-send. Counted only when the current model really is
        still stored (a cache miss is an ordinary re-upload)."""
        msg = self._model_msg(client)
        be = self._resolved(msg)
        if getattr(be, "has_cached_upload", None) is not None and \
                be.has_cached_upload(msg):
            self.late_refetches += 1
        self.dispatch(client, now)

    def _on_availability(self, now: float, ev):
        client = self._by_id.get(ev.client_id)
        if client is None:
            return
        if ev.kind == "leave" and self.is_up(ev.client_id):
            self.available.discard(ev.client_id)
            self._up[self._index[ev.client_id]] = False
            self._mark_busy(ev.client_id, False)  # pipeline dies with it
            self._gen[ev.client_id] += 1  # invalidate in-flight dispatches
            self.departures += 1
            self.strategy.on_leave(self, client, now)
        elif ev.kind == "join" and not self.is_up(ev.client_id):
            self.available.add(ev.client_id)
            self._up[self._index[ev.client_id]] = True
            self.rejoins += 1
            self.strategy.on_join(self, client, now)

    def _on_client_recv(self, now: float, client: FLClient,
                        gen: Optional[int] = None):
        stale = gen is not None and gen != self._gen[client.client_id]
        for msg, ready in client.backend.recv(now):
            if msg.msg_type != "model_sync":
                continue
            if stale or not self.is_up(client.client_id):
                # the model landed at a departed client, or at one that
                # left and rejoined while it was in flight (the rejoin
                # dispatch owns the client's pipeline now)
                continue
            update, _timing, send_start = client.run_round(
                msg, ready, self.local_steps)
            # stamp the pipeline generation: if the client leaves while
            # this update is (logically) training/in flight, the stamp
            # goes stale and the apply guard drops it even if the client
            # has already rejoined with a fresh pipeline
            update.metadata["_gen"] = self._gen[client.client_id]
            self._isend_update(client, update, send_start, attempt=0)

    def _isend_update(self, client: FLClient, update: FLMessage, t: float,
                      attempt: int):
        """Client-side upload with bounded top-level retries: a transfer
        the fault model failed outright is re-issued, 3 attempts total,
        before the update is abandoned (counted discarded)."""
        uh = client.backend.isend(update, t)
        if self._track(uh, f"update>{client.client_id}",
                       self._on_server_recv):
            return
        if attempt < 2:
            self.loop.call_at(
                max(t, uh.start) + self.redispatch_backoff_s,
                f"reupload>{client.client_id}", self._retry_update,
                client=client, update=update, attempt=attempt + 1)
        else:
            self.discarded += 1
            self._mark_busy(client.client_id, False)

    def _retry_update(self, now: float, client: FLClient,
                      update: FLMessage, attempt: int):
        if not self.is_up(client.client_id):
            self.discarded += 1  # departed before the retry could fire
            self._mark_busy(client.client_id, False)
            return
        self._isend_update(client, update, now, attempt)

    def _on_server_recv(self, now: float):
        for msg, ready in self.backend.recv(now):
            if msg.msg_type != "client_update":
                continue
            self.loop.call_at(ready, f"apply<{msg.sender}", self._on_apply,
                              msg=msg)

    def _on_apply(self, now: float, msg: FLMessage):
        self._mark_busy(msg.sender, False)  # dispatch resolved either way
        gen = msg.metadata.get("_gen")
        if not self.is_up(msg.sender) or (
                gen is not None and gen != self._gen.get(msg.sender)):
            # mid-round departure: the sender left while this update was
            # training/in flight (stale generation), or is still down —
            # dynamic-participation semantics say it is not counted
            self.discarded += 1
            return
        client = self._by_id.get(msg.sender)
        version = int(msg.metadata.get("version", msg.round))
        staleness = self.version - version
        rec = UpdateRecord(client=client, payload=msg.payload,
                           weight=float(msg.metadata.get("num_examples", 1)),
                           version=version, staleness=staleness, arrive_t=now)
        self.update_log.append((now, msg.sender, staleness))
        self.strategy.on_update(self, rec, now)

    # -- aggregation -------------------------------------------------------
    def hub_fold(self, rec: UpdateRecord, now: float) -> UpdateRecord:
        """Admit one update into the hub's merge buffer.

        Dense mode (default): charges the buffered payload against the
        server endpoint's memory meter (freed when the buffer merges)
        and returns the record unchanged — O(clients) hub memory,
        today's math bit-for-bit.

        Streaming mode (``streaming_hub=True``): folds the eff-weighted
        update into an O(model) accumulator on the fedavg_reduce
        streaming-accumulate kernel and strips the record's payload to a
        size-only placeholder, so hub memory stays O(model) at any fleet
        size. Virtual payloads fold as counts only and the merge timing
        is identical to the dense path; the staleness discount is taken
        at fold time (same as merge time for the fixed polynomial —
        adaptive-percentile weighting sees a slightly younger window).
        """
        mem = self.backend.endpoint.memory
        if not self.streaming_hub:
            self._charged[id(rec)] = rec.payload.nbytes
            mem.alloc(rec.payload.nbytes, now)
            return rec
        if self._acc is None:
            from repro.fl.aggregator import StreamingAccumulator
            self._acc = StreamingAccumulator()
        if not self._acc_charged:
            mem.alloc(self.global_payload.nbytes, now)
            self._acc_charged = True
        alpha = self.strategy.staleness_weight(rec.staleness)
        self._acc.fold(rec, alpha)
        if isinstance(rec.payload, TensorPayload):
            rec = dataclasses.replace(
                rec, payload=VirtualPayload(rec.payload.nbytes,
                                            tag="hub-folded"))
        return rec

    def aggregate(self, records: Sequence[UpdateRecord], now: float) -> float:
        """Staleness-weighted buffered aggregate; bumps the global version.
        Returns the simulated completion time."""
        records = list(records)
        if self.finished or not records:
            return now
        alphas = [self.strategy.staleness_weight(r.staleness)
                  for r in records]
        eff = [r.weight * a for r, a in zip(records, alphas)]
        nbytes = self.global_payload.nbytes
        acc = self._acc if self.streaming_hub else None
        trees = [r.payload.tree for r in records
                 if isinstance(r.payload, TensorPayload)]
        if acc is not None and acc.count:
            # streaming hub: the buffer is already folded into the
            # accumulator; merge = one divide + damped server update
            merged, stream_agg_s = acc.merged()
            if merged is not None and acc.sum_eff > 0:
                agg_s = stream_agg_s
                lam = self.server_lr * (acc.sum_eff /
                                        max(acc.sum_weight, 1e-12))
                self.global_params = merge_global(self.global_params,
                                                  merged, lam)
                self.global_payload = TensorPayload(self.global_params)
            else:
                agg_s = simulated_agg_time(nbytes, len(records))
                self.global_payload = VirtualPayload(
                    nbytes, tag=f"model:v{self.version + 1}")
            acc.reset()
        elif len(trees) == len(records) and sum(eff) > 0:
            merged, agg_s = fedavg(trees, eff)
            lam = self.server_lr * (sum(eff) /
                                    max(sum(r.weight for r in records), 1e-12))
            self.global_params = merge_global(self.global_params, merged, lam)
            self.global_payload = TensorPayload(self.global_params)
        else:
            agg_s = simulated_agg_time(nbytes, len(records))
            # a merged model is a *new* payload: refresh the virtual tag so
            # object-store content caching doesn't hand out stale-free sends
            self.global_payload = VirtualPayload(
                nbytes, tag=f"model:v{self.version + 1}")
        mig_s = 2 * nbytes / PCIE_BW
        done = max(now, self._agg_busy_until) + mig_s + agg_s
        self._agg_busy_until = done
        self.version += 1
        mem = self.backend.endpoint.memory
        for r in records:
            nb = self._charged.pop(id(r), None)
            if nb is not None:
                mem.free(nb, done)
        if self.cohort_active:
            # re-draw the cohort for the new version; idle members of the
            # fresh sample get their model at merge completion
            self._sample_cohort()
            self.loop.call_at(done, f"cohort-dispatch#v{self.version}",
                              self._cohort_dispatch)
        self.n_aggregations += 1
        self.n_updates_applied += sum(r.count for r in records)
        self.effective_updates += sum(a * r.count
                                      for a, r in zip(alphas, records))
        losses = [getattr(r.client, "last_loss", None) for r in records
                  if r.client is not None]
        losses = [l for l in losses if l is not None]
        self.agg_log.append(AggregationEvent(
            time=done, version=self.version, n_updates=len(records),
            mean_staleness=float(np.mean([r.staleness for r in records])),
            effective_weight=float(sum(alphas)),
            loss=float(np.mean(losses)) if losses else None))
        if (self._target_eff is not None and self.time_to_target is None
                and self.effective_updates >= self._target_eff):
            self.time_to_target = done
        reached_target = (self._target_eff is not None
                          and self.time_to_target is not None)
        reached_cap = (self._max_agg is not None
                       and self.n_aggregations >= self._max_agg)
        if reached_target or reached_cap:
            self.finished = True
            self.finished_at = done
            if self._shared_loop:
                # co-scheduled job: quiesce (dispatch/timer no-op from
                # here) and tell the driver — the shared clock keeps
                # running for the other tenants
                if self.on_finished is not None:
                    self.on_finished(self, done)
            else:
                self.loop.stop()
        return done

    # -- entry point -------------------------------------------------------
    def prepare(self, global_payload, *,
                max_aggregations: Optional[int] = None,
                target_effective_updates: Optional[float] = None,
                start_s: float = 0.0) -> None:
        """Bootstrap this job onto its loop without running it: install
        the payload and caps, schedule availability churn, draw the
        round-0 cohort and fire ``strategy.start``. ``run`` is exactly
        ``prepare`` + ``loop.run`` + ``report``; the multi-job driver
        calls ``prepare`` once per co-scheduled job (with its ``start_s``
        offset) and then runs the shared loop once."""
        self.global_payload = global_payload
        if isinstance(global_payload, TensorPayload):
            self.global_params = global_payload.tree
        self._max_agg = max_aggregations
        self._target_eff = target_effective_updates
        self._start_s = start_s
        if self.availability is not None:
            for ev in self.availability.events:
                self.loop.call_at(ev.time + start_s,
                                  f"avail-{ev.kind}:{ev.client_id}",
                                  self._on_availability, ev=ev)
        if self.cohort_active:
            self._sample_cohort()  # round-0 cohort, before the bootstrap
        self.strategy.start(self, max(self.loop.now, start_s))

    def run(self, global_payload, *, until: float = math.inf,
            max_aggregations: Optional[int] = None,
            target_effective_updates: Optional[float] = None) -> AsyncRunReport:
        if (math.isinf(until) and max_aggregations is None
                and target_effective_updates is None):
            raise ValueError("unbounded run: pass until=, max_aggregations= "
                             "or target_effective_updates=")
        self.prepare(global_payload, max_aggregations=max_aggregations,
                     target_effective_updates=target_effective_updates)
        self.loop.run(until=until)
        return self.report()

    def report(self) -> AsyncRunReport:
        # the stop() that capped the run fires at the *triggering* event;
        # the final merge still runs to completion on the simulated clock
        span = self.loop.now
        if self._shared_loop:
            # on a shared clock loop.now spans every tenant: this job's
            # span runs from its own start to its own finish (or its
            # last aggregation, for until=-bounded runs)
            end = self.finished_at
            if end is None:
                end = self.agg_log[-1].time if self.agg_log else self.loop.now
            span = end - self._start_s
        if self.agg_log:
            span = max(span, self.agg_log[-1].time - self._start_s)
        stal = [s for (_, _, s) in self.update_log]
        losses = [e.loss for e in self.agg_log if e.loss is not None]
        return AsyncRunReport(
            mode=getattr(self.strategy, "name", "?"),
            backend=getattr(self.backend, "name", "?"),
            sim_time=span,
            n_aggregations=self.n_aggregations,
            n_client_updates=self.n_updates_applied,
            effective_updates=self.effective_updates,
            mean_staleness=float(np.mean(stal)) if stal else 0.0,
            aggregations_per_hour=3600.0 * self.n_aggregations
            / max(span, 1e-9),
            client_updates_per_hour=3600.0 * self.n_updates_applied
            / max(span, 1e-9),
            time_to_target=self.time_to_target,
            final_loss=losses[-1] if losses else None,
            n_discarded=self.discarded,
            n_events=len(self.loop.trace),
            n_departures=self.departures,
            n_rejoins=self.rejoins,
            n_transfer_failures=self.transfer_failures,
            n_late_refetches=self.late_refetches)
