"""Aggregation strategies for the event-driven FL scheduler.

Three modes beyond the paper's synchronous loop, selectable from
``FLConfig.mode``:

* ``FedBuffStrategy``     — async buffered aggregation: the server merges a
  staleness-weighted buffer every K arrivals and immediately hands the
  reporting client the newest global model (FedBuff-style; Nguyen et al.).
* ``SemiSyncStrategy``    — quorum + deadline rounds reusing the sync
  straggler policy, but late arrivals are *folded into the next round*
  (with staleness ≥ 1) instead of dropped.
* ``HierarchicalStrategy``— topology-aware per-region relays: clients
  reduce locally over a LAN-class link, then one multi-connection WAN hop
  per region to the hub (Marfoq et al.'s throughput-optimal topology line).
  The hub's FedAvg over weighted relay partials is numerically identical
  to flat FedAvg (tested).

Strategies receive scheduler callbacks (``on_update`` / ``on_timer``) and
use ``sched.dispatch`` / ``sched.aggregate`` / ``sched.timer`` to shape
the event flow.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.message import FLMessage, TensorPayload, VirtualPayload
from repro.core.netsim import (LAN_TCP, Region, Transfer, simulate_transfers,
                               transfer_time)
from repro.fl.aggregator import (fedavg, simulated_agg_time, staleness_weight)
from repro.fl.scheduler import FLScheduler, UpdateRecord


class AggregationStrategy:
    """Base: broadcast-once bootstrap + a staleness weight hook."""

    name = "base"
    staleness_exponent = 0.0

    def staleness_weight(self, staleness: float) -> float:
        return staleness_weight(staleness, self.staleness_exponent)

    def start(self, sched: FLScheduler, now: float):
        self.sched = sched
        sched.dispatch_many(sched.clients, now)

    def on_update(self, sched: FLScheduler, rec: UpdateRecord, now: float):
        raise NotImplementedError

    def on_timer(self, sched: FLScheduler, now: float, **data):
        pass


class FedBuffStrategy(AggregationStrategy):
    """Async FedBuff-style: merge every K arrivals, discount stale updates,
    re-dispatch the newest global to each reporter immediately.

    ``staleness_adaptive`` (FedAsync-style; Xie et al. 2019) scales the
    discount exponent by each update's percentile rank among the staleness
    values observed so far: an update staler than most of the fleet is
    discounted harder than the fixed ``(1+s)^-a`` curve, a fresher-than-
    typical one more gently. With adaptivity off the weighting *is* the
    fixed polynomial — tested."""

    name = "fedbuff"

    def __init__(self, *, buffer_k: int = 3, staleness_exponent: float = 0.5,
                 max_staleness: int = 0, staleness_adaptive: bool = False,
                 adaptive_window: int = 64):
        self.buffer_k = max(1, int(buffer_k))
        self.staleness_exponent = staleness_exponent
        self.max_staleness = int(max_staleness)  # 0 = keep everything
        self.staleness_adaptive = bool(staleness_adaptive)
        self.adaptive_window = int(adaptive_window)
        self.observed: List[float] = []  # rolling staleness window
        self.buffer: List[UpdateRecord] = []

    def staleness_weight(self, staleness: float) -> float:
        exponent = self.staleness_exponent
        if self.staleness_adaptive and self.observed:
            # percentile rank in [0, 1]; exponent spans [0.5a, 1.5a]
            rank = np.mean([o <= staleness for o in self.observed])
            exponent = self.staleness_exponent * (0.5 + float(rank))
        return staleness_weight(staleness, exponent)

    def observe(self, staleness: float):
        self.observed.append(float(staleness))
        if len(self.observed) > self.adaptive_window:
            del self.observed[:-self.adaptive_window]

    def on_update(self, sched: FLScheduler, rec: UpdateRecord, now: float):
        t = now
        self.observe(rec.staleness)
        if self.max_staleness and rec.staleness > self.max_staleness:
            sched.discarded += 1
        else:
            self.buffer.append(rec)
            if len(self.buffer) >= self.buffer_k:
                recs, self.buffer = self.buffer, []
                t = sched.aggregate(recs, now)
        if rec.client is not None:
            sched.dispatch(rec.client, t)


class SemiSyncStrategy(AggregationStrategy):
    """Quorum + deadline rounds; stragglers are folded into the next round
    (their updates arrive with staleness ≥ 1), never dropped."""

    name = "semisync"

    def __init__(self, *, quorum_fraction: float = 1.0,
                 round_deadline_s: float = 0.0,
                 staleness_exponent: float = 0.0):
        self.quorum_fraction = quorum_fraction
        self.round_deadline_s = round_deadline_s
        self.staleness_exponent = staleness_exponent
        self.round_id = 0
        self.collected: List[UpdateRecord] = []

    def start(self, sched: FLScheduler, now: float):
        super().start(sched, now)
        self._arm(sched, now)

    def _need(self, sched) -> int:
        # clamp like the sync server: a quorum can never exceed the fleet
        need = int(np.ceil(self.quorum_fraction * len(sched.clients)))
        return min(max(1, need), len(sched.clients))

    def _arm(self, sched, now: float):
        if self.round_deadline_s > 0:
            sched.timer(now + self.round_deadline_s,
                        f"deadline#r{self.round_id}", self.on_timer,
                        round_id=self.round_id)

    def on_update(self, sched, rec: UpdateRecord, now: float):
        self.collected.append(rec)
        if len(self.collected) >= self._need(sched):
            self._close(sched, now)

    def on_timer(self, sched, now: float, round_id: int):
        if round_id != self.round_id:
            return  # stale timer from an already-closed round
        if self.collected:
            self._close(sched, now)
        else:
            self._arm(sched, now)  # nothing arrived yet: extend the round

    def _close(self, sched, now: float):
        recs, self.collected = self.collected, []
        done = sched.aggregate(recs, now)
        self.round_id += 1
        sched.dispatch_many([r.client for r in recs if r.client is not None],
                            done)
        self._arm(sched, done)


class HierarchicalStrategy(AggregationStrategy):
    """Per-region relay aggregators (topology-aware synchronous rounds).

    Round shape: hub -> one WAN hop per region relay -> LAN fan-out to the
    region's clients; uploads reduce at the relay over LAN, then a single
    multi-connection WAN hop back to the hub. The relay is colocated with
    the region's first client and multiplexes ``relay_conns`` connections
    on its WAN hop — the paper's own Fig 2 concurrency lesson applied to
    topology. The hub merge of weighted relay partials equals flat FedAvg.
    """

    name = "hier"

    def __init__(self, *, relay_link: Region = LAN_TCP, relay_conns: int = 8,
                 staleness_exponent: float = 0.0, wan_compression=None):
        self.relay_link = relay_link
        self.relay_conns = relay_conns
        self.staleness_exponent = staleness_exponent
        # gradient compression on the relay -> hub WAN hop *only*: the
        # LAN-local reduce and the model downlink stay exact, so the hub
        # merges dequantised partials and error feedback keeps each
        # region's residual bounded across rounds. The same CompressStage
        # the backend channels use, keyed per region instead of per peer.
        from repro.core.channel import CompressStage
        self._wan_stage = (CompressStage(wan_compression)
                           if wan_compression is not None else None)

    # -- setup -------------------------------------------------------------
    def start(self, sched: FLScheduler, now: float):
        self.sched = sched
        env = sched.env
        groups: Dict[str, list] = {}
        for c in sched.clients:
            groups.setdefault(env.host(c.client_id).region.name, []).append(c)
        self.groups = dict(sorted(groups.items()))
        probe = FLMessage("model_sync", sched.backend.host_id, "server",
                          payload=sched.global_payload)
        self._be = sched._resolved(probe)
        self._begin_round(sched, now)

    def _wan_conns(self) -> int:
        return max(self._be.policy.conns_per_transfer, self.relay_conns)

    def _lan_hop(self, nbytes: int) -> float:
        ser = self._be.serializer.ser_time(nbytes)
        deser = self._be.serializer.deser_time(nbytes)
        return ser + transfer_time(nbytes, self.relay_link) + deser

    # -- round flow --------------------------------------------------------
    def _begin_round(self, sched, now: float):
        self.pending = {g: {c.client_id for c in cs}
                        for g, cs in self.groups.items()}
        self.partials: Dict[str, List[UpdateRecord]] = {g: []
                                                        for g in self.groups}
        self.hub_records: List[UpdateRecord] = []
        be, env = self._be, sched.env
        nbytes = sched.global_payload.nbytes
        ser_t = be.serializer.ser_time(nbytes)
        hub = env.host(sched.backend.host_id)
        # hub -> relays: one concurrent multi-connection WAN hop per region
        transfers, order, t_ser = [], [], now
        for g, cs in self.groups.items():
            relay_host = env.host(cs[0].client_id)
            region = be._link_region(cs[0].client_id)
            if be.policy.ser_parallel:
                start = now + ser_t
            else:
                t_ser += ser_t
                start = t_ser
            transfers.append(Transfer(
                start=start + be._overhead(region), src=hub, dst=relay_host,
                nbytes=nbytes, conns=self._wan_conns(), link_region=region,
                tag=f"hub->{g}"))
            order.append((g, cs))
        simulate_transfers(transfers)
        deser = be.serializer.deser_time(nbytes)
        for (g, cs), tr in zip(order, transfers):
            relay_t = tr.finish + deser
            # relay fans out to its members over the LAN-class link
            t = relay_t
            for c in cs:
                if be.policy.ser_parallel:
                    ready = relay_t + self._lan_hop(nbytes)
                else:
                    t += be.serializer.ser_time(nbytes)
                    ready = (t + transfer_time(nbytes, self.relay_link)
                             + deser)
                sched.loop.call_at(ready, f"hier-model>{c.client_id}",
                                   self._on_member_model, client=c, group=g)

    def _on_member_model(self, now: float, client, group: str):
        sched = self.sched
        msg = FLMessage("model_sync", f"relay:{group}", client.client_id,
                        round=sched.version, payload=sched.global_payload,
                        metadata={"version": sched.version})
        update, _timing, send_start = client.run_round(
            msg, now, sched.local_steps)
        nb = update.payload.nbytes
        relay_recv = send_start + self._lan_hop(nb)
        rec = UpdateRecord(
            client=client, payload=update.payload,
            weight=float(update.metadata.get("num_examples", 1)),
            version=int(msg.metadata["version"]), staleness=0,
            arrive_t=relay_recv)
        sched.loop.call_at(relay_recv, f"hier-relay<{client.client_id}",
                           self._on_relay_update, group=group, rec=rec)

    def _on_relay_update(self, now: float, group: str, rec: UpdateRecord):
        sched = self.sched
        self.partials[group].append(rec)
        self.pending[group].discard(rec.client.client_id)
        if self.pending[group]:
            return
        recs = self.partials[group]
        weight = float(sum(r.weight for r in recs))
        trees = [r.payload.tree for r in recs
                 if isinstance(r.payload, TensorPayload)]
        if len(trees) == len(recs):
            partial, agg_s = fedavg(trees, [r.weight for r in recs])
            payload = TensorPayload(partial)
        else:
            nb = recs[0].payload.nbytes
            agg_s = simulated_agg_time(nb, len(recs))
            payload = VirtualPayload(nb, tag=f"relay:{group}")
        be = self._be
        region = be._link_region(recs[0].client.client_id)
        wan_payload, codec_s = payload, 0.0
        if self._wan_stage is not None:
            orig_nbytes = payload.nbytes
            wan_payload, info = self._wan_stage.compress(payload, group)
            if info is not None:
                codec = self._wan_stage.codec
                codec_s = (codec.enc_time(orig_nbytes)
                           + codec.dec_time(info["orig_nbytes"]))
                # the hub sees the *decompressed* partial — exactly what
                # the wire can carry, so hier+qsgd aggregates differ from
                # flat FedAvg only by the (error-fed) quantisation noise
                payload = codec.decompress(wan_payload, info)
        nb = wan_payload.nbytes
        wan = (be.serializer.ser_time(nb) + be._overhead(region)
               + transfer_time(nb, region, self._wan_conns())
               + be.serializer.deser_time(nb) + codec_s)
        hub_rec = UpdateRecord(client=recs[0].client, payload=payload,
                               weight=weight, version=recs[0].version,
                               staleness=0, arrive_t=now + agg_s + wan,
                               count=len(recs))
        sched.loop.call_at(hub_rec.arrive_t, f"hier-hub<{group}",
                           self._on_hub_partial, rec=hub_rec)

    def _on_hub_partial(self, now: float, rec: UpdateRecord):
        sched = self.sched
        self.hub_records.append(rec)
        if len(self.hub_records) < len(self.groups):
            return
        recs, self.hub_records = self.hub_records, []
        done = sched.aggregate(recs, now)
        if not sched.loop.stopped:
            self._begin_round(sched, done)


def make_strategy(cfg, num_clients: Optional[int] = None,
                  **overrides) -> AggregationStrategy:
    """Strategy factory from ``FLConfig`` knobs (mode + buffer/staleness)."""
    n = num_clients or cfg.num_clients
    mode = cfg.mode
    compression = getattr(cfg, "compression", "none")
    if mode == "fedbuff":
        k = cfg.buffer_k or max(2, n // 2)
        return FedBuffStrategy(buffer_k=k,
                               staleness_exponent=cfg.staleness_exponent,
                               max_staleness=cfg.max_staleness,
                               staleness_adaptive=getattr(
                                   cfg, "staleness_adaptive", False),
                               **overrides)
    if mode == "semisync":
        return SemiSyncStrategy(quorum_fraction=cfg.quorum_fraction,
                                round_deadline_s=cfg.round_deadline_s,
                                staleness_exponent=cfg.staleness_exponent,
                                **overrides)
    if mode == "hier":
        overrides.setdefault(
            "wan_compression",
            None if compression in ("", "none") else compression)
        return HierarchicalStrategy(
            staleness_exponent=cfg.staleness_exponent, **overrides)
    raise KeyError(f"unknown scheduler mode '{mode}' "
                   "(sync rounds use FLServer.run_round)")
