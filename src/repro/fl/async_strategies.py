"""Aggregation strategies for the event-driven FL scheduler.

Three modes beyond the paper's synchronous loop, selectable from
``FLConfig.mode``:

* ``FedBuffStrategy``     — async buffered aggregation: the server merges a
  staleness-weighted buffer every K arrivals and immediately hands the
  reporting client the newest global model (FedBuff-style; Nguyen et al.).
* ``SemiSyncStrategy``    — quorum + deadline rounds reusing the sync
  straggler policy, but late arrivals are *folded into the next round*
  (with staleness ≥ 1) instead of dropped.
* ``HierarchicalStrategy``— topology-aware per-region relays: clients
  reduce locally over a LAN-class link, then one multi-connection WAN hop
  per region to the hub (Marfoq et al.'s throughput-optimal topology line).
  The hub's FedAvg over weighted relay partials is numerically identical
  to flat FedAvg (tested).

Strategies receive scheduler callbacks (``on_update`` / ``on_timer``) and
use ``sched.dispatch`` / ``sched.aggregate`` / ``sched.timer`` to shape
the event flow.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.message import FLMessage, TensorPayload, VirtualPayload
from repro.core.netsim import (LAN_TCP, Region, Transfer, simulate_transfers,
                               transfer_time)
from repro.fl.aggregator import (fedavg, simulated_agg_time, staleness_weight)
from repro.fl.scheduler import FLScheduler, UpdateRecord


class AggregationStrategy:
    """Base: broadcast-once bootstrap + staleness weight and churn hooks."""

    name = "base"
    staleness_exponent = 0.0

    def staleness_weight(self, staleness: float) -> float:
        return staleness_weight(staleness, self.staleness_exponent)

    def start(self, sched: FLScheduler, now: float):
        self.sched = sched
        sched.dispatch_many(sched.clients, now)

    def on_update(self, sched: FLScheduler, rec: UpdateRecord, now: float):
        raise NotImplementedError

    def on_timer(self, sched: FLScheduler, now: float, **data):
        pass

    # -- churn (fl/fault.AvailabilityTrace) -----------------------------
    def on_leave(self, sched: FLScheduler, client, now: float):
        """A client departed. In-flight updates from it are discarded by
        the scheduler's apply guard; strategies with round structure
        override to re-check quorums."""

    def on_join(self, sched: FLScheduler, client, now: float):
        """A client (re)joined: hand it the current model. Over grpc+s3
        this is the S3 late-join re-fetch (cached object, no sender
        re-upload); round-structured strategies may instead fold the
        client in at the next round boundary."""
        sched.rejoin(client, now)


class FedBuffStrategy(AggregationStrategy):
    """Async FedBuff-style: merge every K arrivals, discount stale updates,
    re-dispatch the newest global to each reporter immediately.

    ``staleness_adaptive`` (FedAsync-style; Xie et al. 2019) scales the
    discount exponent by each update's percentile rank among the staleness
    values observed so far: an update staler than most of the fleet is
    discounted harder than the fixed ``(1+s)^-a`` curve, a fresher-than-
    typical one more gently. With adaptivity off the weighting *is* the
    fixed polynomial — tested."""

    name = "fedbuff"

    def __init__(self, *, buffer_k: int = 3, staleness_exponent: float = 0.5,
                 max_staleness: int = 0, staleness_adaptive: bool = False,
                 adaptive_window: int = 64):
        self.buffer_k = max(1, int(buffer_k))
        self.staleness_exponent = staleness_exponent
        self.max_staleness = int(max_staleness)  # 0 = keep everything
        self.staleness_adaptive = bool(staleness_adaptive)
        self.adaptive_window = int(adaptive_window)
        self.observed: List[float] = []  # rolling staleness window
        self.buffer: List[UpdateRecord] = []

    def staleness_weight(self, staleness: float) -> float:
        exponent = self.staleness_exponent
        if self.staleness_adaptive and self.observed:
            # percentile rank in [0, 1]; exponent spans [0.5a, 1.5a]
            rank = np.mean([o <= staleness for o in self.observed])
            exponent = self.staleness_exponent * (0.5 + float(rank))
        return staleness_weight(staleness, exponent)

    def observe(self, staleness: float):
        self.observed.append(float(staleness))
        if len(self.observed) > self.adaptive_window:
            del self.observed[:-self.adaptive_window]

    def on_update(self, sched: FLScheduler, rec: UpdateRecord, now: float):
        t = now
        self.observe(rec.staleness)
        if self.max_staleness and rec.staleness > self.max_staleness:
            sched.discarded += 1
        else:
            self.buffer.append(sched.hub_fold(rec, now))
            if len(self.buffer) >= self.buffer_k:
                recs, self.buffer = self.buffer, []
                t = sched.aggregate(recs, now)
        if rec.client is not None:
            sched.dispatch(rec.client, t)


class SemiSyncStrategy(AggregationStrategy):
    """Quorum + deadline rounds; stragglers are folded into the next round
    (their updates arrive with staleness ≥ 1), never dropped."""

    name = "semisync"

    def __init__(self, *, quorum_fraction: float = 1.0,
                 round_deadline_s: float = 0.0,
                 staleness_exponent: float = 0.0):
        self.quorum_fraction = quorum_fraction
        self.round_deadline_s = round_deadline_s
        self.staleness_exponent = staleness_exponent
        self.round_id = 0
        self.collected: List[UpdateRecord] = []

    def start(self, sched: FLScheduler, now: float):
        super().start(sched, now)
        self._arm(sched, now)

    def _need(self, sched) -> int:
        # clamp like the sync server — against the *eligible* fleet (live
        # cohort members under cohort sampling, the live fleet otherwise):
        # a quorum over departed or unsampled clients would stall forever
        n_live = sched.eligible_count()
        need = int(np.ceil(self.quorum_fraction * max(n_live, 1)))
        return min(max(1, need), max(n_live, 1))

    def _arm(self, sched, now: float):
        if self.round_deadline_s > 0:
            sched.timer(now + self.round_deadline_s,
                        f"deadline#r{self.round_id}", self.on_timer,
                        round_id=self.round_id)

    def on_update(self, sched, rec: UpdateRecord, now: float):
        self.collected.append(sched.hub_fold(rec, now))
        if len(self.collected) >= self._need(sched):
            self._close(sched, now)

    def on_leave(self, sched, client, now: float):
        # a departure shrinks the quorum: the collected set may already
        # satisfy it (mid-round departures must not stall the round)
        if self.collected and len(self.collected) >= self._need(sched):
            self._close(sched, now)

    def on_timer(self, sched, now: float, round_id: int):
        if round_id != self.round_id:
            return  # stale timer from an already-closed round
        if self.collected:
            self._close(sched, now)
        else:
            self._arm(sched, now)  # nothing arrived yet: extend the round

    def _close(self, sched, now: float):
        recs, self.collected = self.collected, []
        done = sched.aggregate(recs, now)
        self.round_id += 1
        sched.dispatch_many([r.client for r in recs if r.client is not None],
                            done)
        self._arm(sched, done)


class HierarchicalStrategy(AggregationStrategy):
    """Per-region relay aggregators (topology-aware synchronous rounds).

    Round shape: hub -> one WAN hop per region relay -> LAN fan-out to the
    region's clients; uploads reduce at the relay over LAN, then a single
    multi-connection WAN hop back to the hub. The relay is colocated with
    the region's first client and multiplexes ``relay_conns`` connections
    on its WAN hop — the paper's own Fig 2 concurrency lesson applied to
    topology. The hub merge of weighted relay partials equals flat FedAvg.

    The relay -> hub hop is a *real backend channel over the topology's
    graph edge* (relay host -> hub), not an analytic formula: each region
    gets its own backend instance — same family as the deployment's —
    whose wire stack carries the WAN compression / wire codec / chunking,
    so the hop is faultable by the fabric's LinkFaultModel (chunk loss,
    NACK retransmits, blackouts), cacheable by the object store, and
    decodes at the hub by recorded provenance like any other wire.
    """

    name = "hier"

    def __init__(self, *, relay_link: Region = LAN_TCP, relay_conns: int = 8,
                 staleness_exponent: float = 0.0, wan_compression=None,
                 wan_wire_codec=None, chunk_mb: float = 0.0,
                 region_quorum: float = 0.5, relay_depth: int = 1):
        self.relay_link = relay_link
        self.relay_conns = relay_conns
        self.staleness_exponent = staleness_exponent
        # reduction-tree depth on the upload side: 1 = every region relay
        # ships straight to the hub (the historical single-tier path,
        # bit-for-bit); D > 1 inserts D-1 tiers of super-relays between
        # the region relays and the hub, each folding its children's
        # partials before one upstream hop. The downlink stays
        # single-tier — the hub's broadcast already fans out through the
        # region relays, and multi-download (S3) makes a nested downlink
        # redundant.
        self.relay_depth = max(1, int(relay_depth))
        # relay-level quorum: a region with fewer than
        # ceil(region_quorum * members) live clients is *skipped* for the
        # round (its relay sends nothing, the hub does not wait) and
        # folded back in at the first round boundary after a rejoin
        self.region_quorum = float(region_quorum)
        self.rounds_with_skips = 0
        self.idle_since: Optional[float] = None
        # gradient compression on the relay -> hub WAN hop *only*: the
        # LAN-local reduce and the model downlink stay exact, so the hub
        # merges dequantised partials and error feedback keeps each
        # region's residual bounded across rounds. The codecs ride the
        # relay backends' own channels — one backend per region, so the
        # EF stream is naturally per-region.
        self.wan_compression = wan_compression
        self.wan_wire_codec = wan_wire_codec
        self.chunk_mb = float(chunk_mb)
        self._relay_be: Dict[str, object] = {}  # region -> relay backend

    # -- setup -------------------------------------------------------------
    def start(self, sched: FLScheduler, now: float):
        self.sched = sched
        env = sched.env
        groups: Dict[str, list] = {}
        for c in sched.clients:
            groups.setdefault(env.host(c.client_id).region.name, []).append(c)
        self.groups = dict(sorted(groups.items()))
        probe = FLMessage("model_sync", sched.backend.host_id, "server",
                          payload=sched.global_payload)
        self._be = sched._resolved(probe)
        self._group_meta: Dict[str, tuple] = {}  # region -> (client, count)
        # per-round relay election: the region's first *live* member
        # (set in _begin_round; fan-out, member uploads and the WAN
        # partial must all agree on the relay host, also under churn)
        self._relay_host: Dict[str, str] = {}
        self._build_tree()
        self._begin_round(sched, now)

    # -- relay tree (relay_depth > 1) --------------------------------------
    def _build_tree(self):
        """Chunk the sorted region list into D-1 tiers of super-relays.

        Tier t groups the previous tier's nodes into chunks of
        ``fan = max(2, ceil(sqrt(len)))``; a tier that collapses to one
        node ends the tree early (more depth would only relabel it).
        ``_parent`` maps every node (region name or tier node id) to its
        parent, 'hub' at the top."""
        self._parent = {g: "hub" for g in self.groups}
        self._children: Dict[str, list] = {}
        self._top = list(self.groups)
        if self.relay_depth <= 1:
            return
        level = list(self.groups)
        for tier in range(1, self.relay_depth):
            if len(level) <= 1:
                break
            fan = max(2, math.ceil(len(level) ** 0.5))
            nxt = []
            for i in range(0, len(level), fan):
                node = f"tier{tier}.{i // fan}"
                kids = level[i:i + fan]
                self._children[node] = kids
                for kd in kids:
                    self._parent[kd] = node
                nxt.append(node)
            level = nxt
        for node in level:
            self._parent[node] = "hub"
        self._top = level

    def _desc_groups(self, node: str) -> list:
        """Descendant region names of ``node`` in region-sorted order."""
        if node in self.groups:
            return [node]
        out = []
        for kd in self._children[node]:
            out.extend(self._desc_groups(kd))
        return out

    def _node_host(self, node: str) -> str:
        """The host a tree node runs on: a region's elected relay, or —
        for a super-relay — the relay of its first round-active
        descendant region (falling back to the first descendant when no
        round is open)."""
        if node in self.groups:
            return self._relay_id(node)
        active = getattr(self, "_round_active", None)
        desc = self._desc_groups(node)
        for g in desc:
            if active is None or g in active:
                return self._relay_id(g)
        return self._relay_id(desc[0])

    def _wan_conns(self) -> int:
        return max(self._be.policy.conns_per_transfer, self.relay_conns)

    def _relay_id(self, group: str) -> str:
        """The host currently acting as ``group``'s relay: elected at
        round begin among live members; static first member as the
        fallback for paths that run with no round open (skip records)."""
        return self._relay_host.get(group, self.groups[group][0].client_id)

    def _relay_backend(self, group: str):
        return self._backend_at(self._relay_id(group))

    def _backend_at(self, host_id: str):
        """A relay's channel: same backend family as the deployment,
        colocated with ``host_id`` (a region's elected relay or a
        super-relay tier node's host), WAN hop multiplexed over
        ``relay_conns`` connections. Cached per host — if churn migrates
        a region's relay, the new host starts a fresh channel (and a
        fresh error-feedback stream, as a real relay would)."""
        be = self._relay_be.get(host_id)
        if be is None:
            import dataclasses as _dc

            from repro.core.backends import make_backend
            from repro.core.backends.grpc_s3 import GrpcS3Backend
            sched = self.sched
            be = make_backend(
                getattr(sched.backend, "name", "grpc"), sched.env,
                sched.backend.fabric, host_id,
                store=getattr(sched.backend, "store", None),
                compression=self.wan_compression,
                wire_codec=self.wan_wire_codec, chunk_mb=self.chunk_mb)
            for sub in (be, getattr(be, "grpc", None),
                        getattr(be, "membuff", None)):
                if sub is None or isinstance(sub, GrpcS3Backend) \
                        or not hasattr(sub, "policy"):
                    continue  # multipart GET *is* grpc+s3's concurrency
                sub.policy = _dc.replace(
                    sub.policy, conns_per_transfer=max(
                        sub.policy.conns_per_transfer, self.relay_conns))
            self._relay_be[host_id] = be
        return be

    def wan_ef_states(self):
        """Per-region error-feedback residuals living on the relay
        channels' CompressStages (fidelity probes: tests, fig7)."""
        from repro.core.channel import CompressStage, WireCompressStage
        states = []
        for be in self._relay_be.values():
            channels = [getattr(sub, "channel", None)
                        for sub in (be, getattr(be, "grpc", None),
                                    getattr(be, "membuff", None),
                                    getattr(be, "s3", None))]
            for ch in channels:
                if ch is None:
                    continue
                for st in ch.stages:
                    if isinstance(st, CompressStage) and \
                            not isinstance(st, WireCompressStage):
                        states.extend(st._state.values())
        return states

    def _lan_link(self, src_id: str, dst_id: str) -> Region:
        """The intra-region leg: the topology's explicit DC-class edge
        when the graph declares one (multi_hub / custom EdgeSpecs), else
        the configured ``relay_link`` class. WAN-rule fallback edges are
        deliberately ignored — colocated silos reduce over the local
        fabric, not through the hub link."""
        links = getattr(self.sched.env, "links", None) or {}
        edge = links.get((src_id, dst_id))
        if edge is not None and (edge.lan_class
                                 or edge.region.name.startswith("lan")):
            return edge.region
        return self.relay_link

    def _lan_hop(self, nbytes: int, src_id: str = "", dst_id: str = "") -> float:
        link = self._lan_link(src_id, dst_id)
        ser = self._be.serializer.ser_time(nbytes)
        deser = self._be.serializer.deser_time(nbytes)
        return ser + transfer_time(nbytes, link) + deser

    # -- round flow --------------------------------------------------------
    def _live_groups(self, sched) -> Dict[str, list]:
        """Regions meeting the relay quorum this round, with their live
        members (insertion order preserved from ``self.groups``)."""
        active: Dict[str, list] = {}
        for g, cs in self.groups.items():
            live = [c for c in cs if sched.is_up(c.client_id)]
            need = max(1, int(np.ceil(self.region_quorum * len(cs))))
            if len(live) >= need:
                active[g] = live
        return active

    def _begin_round(self, sched, now: float):
        active = self._live_groups(sched)
        if not active:
            # every region below quorum: stall until a rejoin restarts us
            # (not counted as a skipping round — no round began)
            self.idle_since = now
            return
        if len(active) < len(self.groups):
            self.rounds_with_skips += 1
        self.idle_since = None
        self.pending = {g: {c.client_id for c in cs}
                        for g, cs in active.items()}
        self.partials: Dict[str, List[UpdateRecord]] = {g: [] for g in active}
        self._round_active = set(active)
        if self.relay_depth > 1:
            # arm the super-relay tiers: each node waits on the children
            # with at least one round-active descendant region; the hub
            # waits on the active top-tier nodes
            self._node_expected: Dict[str, set] = {}
            self._node_partials: Dict[str, List[UpdateRecord]] = {}
            for nd, kids in self._children.items():
                exp = {kd for kd in kids
                       if any(g in self._round_active
                              for g in self._desc_groups(kd))}
                if exp:
                    self._node_expected[nd] = exp
                    self._node_partials[nd] = []
            self.expected = {nd for nd in self._top
                             if any(g in self._round_active
                                    for g in self._desc_groups(nd))}
        else:
            self.expected = set(active)  # groups the hub still waits on
        self.hub_records: List[UpdateRecord] = []
        be, env = self._be, sched.env
        nbytes = sched.global_payload.nbytes
        ser_t = be.serializer.ser_time(nbytes)
        hub = env.host(sched.backend.host_id)
        # hub -> relays: one concurrent multi-connection WAN hop per region
        transfers, order, t_ser = [], [], now
        for g, cs in active.items():
            # elect this round's relay: the region's first live member
            self._relay_host[g] = cs[0].client_id
            relay_host = env.host(cs[0].client_id)
            region = be._link_region(cs[0].client_id)
            if be.policy.ser_parallel:
                start = now + ser_t
            else:
                t_ser += ser_t
                start = t_ser
            transfers.append(Transfer(
                start=start + be._overhead(region), src=hub, dst=relay_host,
                nbytes=nbytes, conns=self._wan_conns(), link_region=region,
                tag=f"hub->{g}"))
            order.append((g, cs))
        simulate_transfers(transfers)
        deser = be.serializer.deser_time(nbytes)
        for (g, cs), tr in zip(order, transfers):
            relay_t = tr.finish + deser
            relay_id = self._relay_host[g]
            # relay fans out to its members over the intra-region leg
            t = relay_t
            for c in cs:
                if be.policy.ser_parallel:
                    ready = relay_t + self._lan_hop(nbytes, relay_id,
                                                    c.client_id)
                else:
                    t += be.serializer.ser_time(nbytes)
                    ready = (t + transfer_time(
                        nbytes, self._lan_link(relay_id, c.client_id))
                        + deser)
                sched.loop.call_at(ready, f"hier-model>{c.client_id}",
                                   self._on_member_model, client=c, group=g)

    # -- churn -----------------------------------------------------------
    def on_leave(self, sched, client, now: float):
        g = sched.env.host(client.client_id).region.name
        self._member_gone(g, client.client_id, now)

    def on_join(self, sched, client, now: float):
        # folded in at the next round boundary (the relay re-counts its
        # live members in _begin_round); if every region had churned below
        # quorum the round loop stalled — the rejoin restarts it
        if self.idle_since is not None and self._live_groups(sched):
            self._begin_round(sched, now)

    def _member_gone(self, group: str, client_id: str, now: float):
        pend = getattr(self, "pending", {}).get(group)
        if pend is None or client_id not in pend:
            return
        pend.discard(client_id)
        if not pend:
            self._finish_group(group, now)

    def _on_member_model(self, now: float, client, group: str):
        sched = self.sched
        if not sched.is_up(client.client_id):
            # departed while the model was in flight: mid-round departure
            self._member_gone(group, client.client_id, now)
            return
        if client.client_id not in self.pending.get(group, set()):
            return  # superseded round (the region closed without us)
        msg = FLMessage("model_sync", f"relay:{group}", client.client_id,
                        round=sched.version, payload=sched.global_payload,
                        metadata={"version": sched.version})
        update, _timing, send_start = client.run_round(
            msg, now, sched.local_steps)
        nb = update.payload.nbytes
        relay_recv = send_start + self._lan_hop(
            nb, client.client_id, self._relay_id(group))
        rec = UpdateRecord(
            client=client, payload=update.payload,
            weight=float(update.metadata.get("num_examples", 1)),
            version=int(msg.metadata["version"]), staleness=0,
            arrive_t=relay_recv)
        sched.loop.call_at(relay_recv, f"hier-relay<{client.client_id}",
                           self._on_relay_update, group=group, rec=rec)

    def _on_relay_update(self, now: float, group: str, rec: UpdateRecord):
        cid = rec.client.client_id
        if cid not in self.pending.get(group, set()):
            return  # departed mid-round / region already closed: dropped
        self.partials[group].append(rec)
        self.pending[group].discard(cid)
        if self.pending[group]:
            return
        self._finish_group(group, now)

    def _finish_group(self, group: str, now: float):
        """Every pending member of ``group`` reported or departed: relay
        reduces and ships its partial over the WAN — or, churned empty,
        notifies the hub with a bare control record so the round closes."""
        sched = self.sched
        recs = self.partials[group]
        be = self._be
        if not recs:
            member = self.groups[group][0]
            region = be._link_region(member.client_id)
            self._notify_skip(group,
                              now + be._overhead(region) + region.latency)
            return
        weight = float(sum(r.weight for r in recs))
        trees = [r.payload.tree for r in recs
                 if isinstance(r.payload, TensorPayload)]
        if len(trees) == len(recs):
            partial, agg_s = fedavg(trees, [r.weight for r in recs])
            payload = TensorPayload(partial)
        else:
            nb = recs[0].payload.nbytes
            agg_s = simulated_agg_time(nb, len(recs))
            # the tag carries the version: each round's partial is a new
            # object (the relay channel's store cache must not re-serve
            # last round's bytes for this round's merge)
            payload = VirtualPayload(
                nb, tag=f"relay:{group}:v{recs[0].version}")
        self._group_meta[group] = (recs[0].client, len(recs))
        self._send_partial(group, payload, weight, recs[0].version,
                           len(recs), now + agg_s, 0)

    def _notify_skip(self, node: str, t: float):
        """Resolve ``node`` as a skip at its parent — the hub for
        single-tier trees (and top-tier nodes), the next super-relay up
        otherwise, so a churned-empty region still closes every tier."""
        parent = self._parent.get(node, "hub")
        if parent == "hub":
            self.sched.loop.call_at(t, f"hier-skip<{node}",
                                    self._on_hub_partial, rec=None,
                                    group=node)
        else:
            self.sched.loop.call_at(t, f"hier-skip<{node}",
                                    self._on_node_skip, node=parent,
                                    child=node)

    def _send_partial(self, group: str, payload, weight: float,
                      version: int, count: int, t: float, attempt: int):
        """Ship one tree node's reduced partial one hop upstream over the
        node's real backend channel (graph edge node-host -> parent
        host, the hub at the top): compression / wire codec / chunking
        ride the channel, the fabric's fault model can lose chunks, and
        a transfer the model fails outright is re-issued with bounded
        retries before the node resolves as a skip — the hub never
        wedges on a dead WAN edge. ``group`` is a region name or a
        ``tierN.M`` super-relay node id."""
        sched = self.sched
        parent = self._parent.get(group, "hub")
        relay = self._backend_at(self._node_host(group))
        dest = sched.backend.host_id if parent == "hub" \
            else self._node_host(parent)
        msg = FLMessage("relay_partial", relay.host_id, dest, round=version,
                        payload=payload,
                        metadata={"group": group, "weight": weight,
                                  "count": count, "version": version})
        h = relay.isend(msg, t)
        if getattr(h, "failed", False):
            sched.transfer_failures += 1
            if attempt < 2:
                sched.loop.call_at(
                    max(t, h.start) + sched.redispatch_backoff_s,
                    f"hier-wan-retry<{group}",
                    lambda now, g=group, p=payload, w=weight, v=version,
                    c=count, a=attempt:
                    self._send_partial(g, p, w, v, c, now, a + 1))
            else:
                self._notify_skip(group, h.start)
            return
        if parent == "hub":
            sched.loop.call_at(h.inbox_t, f"hier-hub<{group}",
                               self._on_hub_arrival)
        else:
            sched.loop.call_at(h.inbox_t, f"hier-tier<{parent}",
                               self._on_tier_arrival, node=parent,
                               be=self._backend_at(dest))

    # -- super-relay tiers (relay_depth > 1) -------------------------------
    def _on_tier_arrival(self, now: float, node: str, be):
        """Drain a super-relay's endpoint: child partials decode by their
        recorded wire stages, then join the node's fold at their
        decode-complete time (the hub-arrival flow, one tier down)."""
        sched = self.sched
        for msg, ready in be.recv(now):
            if msg.msg_type != "relay_partial":
                continue
            rec = UpdateRecord(client=None, payload=msg.payload,
                               weight=float(msg.metadata["weight"]),
                               version=int(msg.metadata["version"]),
                               staleness=0, arrive_t=ready,
                               count=int(msg.metadata["count"]))
            sched.loop.call_at(ready, f"hier-fold<{node}",
                               self._on_node_partial, node=node, rec=rec,
                               child=msg.metadata["group"])

    def _on_node_skip(self, now: float, node: str, child: str):
        self._on_node_partial(now, node=node, rec=None, child=child)

    def _on_node_partial(self, now: float, node: str,
                         rec: Optional[UpdateRecord], child: str):
        """One child of super-relay ``node`` resolved (partial or skip);
        when the last one lands the node folds and ships upstream."""
        exp = self._node_expected.get(node)
        if exp is None or child not in exp:
            return  # superseded round
        exp.discard(child)
        if rec is not None:
            self._node_partials[node].append(rec)
        if exp:
            return
        recs = self._node_partials.pop(node)
        del self._node_expected[node]
        if not recs:  # every child skipped: propagate upward
            self._notify_skip(node, now)
            return
        weight = float(sum(r.weight for r in recs))
        count = int(sum(r.count for r in recs))
        version = recs[0].version
        trees = [r.payload.tree for r in recs
                 if isinstance(r.payload, TensorPayload)]
        if len(trees) == len(recs):
            partial, agg_s = fedavg(trees, [r.weight for r in recs])
            payload = TensorPayload(partial)
        else:
            nb = max(r.payload.nbytes for r in recs)
            agg_s = simulated_agg_time(nb, len(recs))
            payload = VirtualPayload(nb, tag=f"relay:{node}:v{version}")
        self._send_partial(node, payload, weight, version, count,
                           now + agg_s, 0)

    def _on_hub_arrival(self, now: float):
        """Drain the hub's endpoint: the relay partial decodes by its
        recorded wire stages (dequantised / inflated / reassembled), then
        joins the merge at its decode-complete time."""
        sched = self.sched
        for msg, ready in sched.backend.recv(now):
            if msg.msg_type != "relay_partial":
                continue
            g = msg.metadata["group"]
            client, _ = self._group_meta.get(g, (None, 0))
            rec = UpdateRecord(client=client, payload=msg.payload,
                               weight=float(msg.metadata["weight"]),
                               version=int(msg.metadata["version"]),
                               staleness=0, arrive_t=ready,
                               count=int(msg.metadata["count"]))
            sched.loop.call_at(ready, f"hier-merge<{g}",
                               self._on_hub_partial, rec=rec, group=g)

    def _on_hub_partial(self, now: float, rec: Optional[UpdateRecord],
                        group: str):
        sched = self.sched
        self.expected.discard(group)
        if rec is not None:
            self.hub_records.append(rec)
        if self.expected:
            return
        recs, self.hub_records = self.hub_records, []
        # every participating region churned empty -> no merge this round
        done = sched.aggregate(recs, now) if recs else now
        if not sched.loop.stopped:
            self._begin_round(sched, done)


def make_strategy(cfg, num_clients: Optional[int] = None,
                  **overrides) -> AggregationStrategy:
    """Strategy factory from ``FLConfig`` knobs (mode + buffer/staleness)."""
    n = num_clients or cfg.num_clients
    mode = cfg.mode
    compression = getattr(cfg, "compression", "none")
    if mode == "fedbuff":
        k = cfg.buffer_k or max(2, n // 2)
        return FedBuffStrategy(buffer_k=k,
                               staleness_exponent=cfg.staleness_exponent,
                               max_staleness=cfg.max_staleness,
                               staleness_adaptive=getattr(
                                   cfg, "staleness_adaptive", False),
                               **overrides)
    if mode == "semisync":
        return SemiSyncStrategy(quorum_fraction=cfg.quorum_fraction,
                                round_deadline_s=cfg.round_deadline_s,
                                staleness_exponent=cfg.staleness_exponent,
                                **overrides)
    if mode == "hier":
        overrides.setdefault(
            "wan_compression",
            None if compression in ("", "none") else compression)
        wire = getattr(cfg, "wire_codec", "none")
        overrides.setdefault("wan_wire_codec",
                             None if wire in ("", "none") else wire)
        overrides.setdefault("chunk_mb", getattr(cfg, "chunk_mb", 0.0))
        overrides.setdefault("region_quorum",
                             getattr(cfg, "region_quorum", 0.5))
        overrides.setdefault("relay_conns", getattr(cfg, "relay_conns", 8))
        overrides.setdefault("relay_depth", getattr(cfg, "relay_depth", 1))
        return HierarchicalStrategy(
            staleness_exponent=cfg.staleness_exponent, **overrides)
    if mode == "vertical":
        from repro.fl.vertical import VerticalStrategy
        overrides.setdefault("cut_layer", getattr(cfg, "cut_layer", 1))
        overrides.setdefault("batches_per_round",
                             getattr(cfg, "batches_per_round", 8))
        return VerticalStrategy(**overrides)
    raise KeyError(
        f"unknown scheduler mode '{mode}': event-driven modes are "
        f"'fedbuff' | 'semisync' | 'hier' | 'vertical' (sync rounds use "
        f"FLServer.run_round)")
